#!/usr/bin/env python3
"""Static cross-checker for the `tod` crate, for containers without cargo.

The crate has zero external dependencies, so every non-`std` name must
resolve inside the crate itself. That makes a useful subset of rustc's
name resolution implementable with text analysis:

  1. module-tree construction from lib.rs / mod.rs `pub mod` items;
  2. per-module public item inventory (struct/enum/trait/fn/const/type);
  3. resolution of every `use crate::...` (and `use tod::...` from
     tests/benches/examples) against that inventory;
  4. enum-variant reference checks (`Enum::Variant` paths);
  5. struct-literal field checks against the struct definition;
  6. trait-impl completeness (required methods without default bodies);
  7. method-existence probe for `.method(` calls against the union of
     inherent/trait methods (advisory: no type inference).

It is deliberately conservative: anything it cannot resolve with
confidence is reported as `advisory`, not `error`. Errors are meant to
be real compile breaks worth fixing before the first `cargo build`.

Usage:  python3 tools/rust_static_check.py [--root rust] [--advisory]
Exit:   non-zero iff any `error`-severity finding is emitted.
"""

import argparse
import os
import re
import sys
from collections import defaultdict

# --------------------------------------------------------------------------
# masking: blank comments / strings / char literals, preserve byte layout
# --------------------------------------------------------------------------

def mask_source(src: str) -> str:
    out = list(src)
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        two = src[i : i + 2]
        if two == "//":
            j = i
            while j < n and src[j] != "\n":
                out[j] = " "
                j += 1
            i = j
        elif two == "/*":
            depth, j = 1, i + 2
            out[i] = out[i + 1] = " "
            while j < n and depth:
                if src[j : j + 2] == "/*":
                    depth += 1
                    out[j] = out[j + 1] = " "
                    j += 2
                elif src[j : j + 2] == "*/":
                    depth -= 1
                    out[j] = out[j + 1] = " "
                    j += 2
                else:
                    if src[j] != "\n":
                        out[j] = " "
                    j += 1
            i = j
        elif c == '"':
            # raw string?
            back = i - 1
            hashes = 0
            while back >= 0 and src[back] == "#":
                hashes += 1
                back -= 1
            is_raw = back >= 0 and src[back] == "r" and (back == 0 or not (src[back - 1].isalnum() or src[back - 1] == "_") or src[back - 1] == "b")
            j = i + 1
            if is_raw and hashes >= 0:
                close = '"' + "#" * hashes
                end = src.find(close, j)
                end = n if end == -1 else end + len(close)
                for k in range(i, end):
                    if src[k] != "\n":
                        out[k] = " "
                i = end
            else:
                while j < n:
                    if src[j] == "\\":
                        j += 2
                        continue
                    if src[j] == '"':
                        j += 1
                        break
                    j += 1
                for k in range(i, min(j, n)):
                    if src[k] != "\n":
                        out[k] = " "
                i = j
        elif c == "'":
            # char literal vs lifetime: 'x' or '\x' is a literal; 'ident is a lifetime
            if i + 2 < n and (src[i + 1] == "\\" or src[i + 2] == "'"):
                j = i + 1
                while j < n and src[j] != "'":
                    if src[j] == "\\":
                        j += 1
                    j += 1
                j += 1
                for k in range(i, min(j, n)):
                    out[k] = " "
                i = j
            else:
                i += 1
        else:
            i += 1
    return "".join(out)


# --------------------------------------------------------------------------
# crate model
# --------------------------------------------------------------------------

ITEM_RE = re.compile(
    r"^\s*(?:pub(?:\(\w+\))?\s+)?(struct|enum|trait|fn|const|static|type|union|mod|macro_rules!)\s+([A-Za-z_][A-Za-z0-9_]*)",
    re.M,
)

def line_of(src: str, pos: int) -> int:
    return src.count("\n", 0, pos) + 1


class Module:
    def __init__(self, path, file):
        self.path = path          # e.g. "scenario::harness"
        self.file = file
        self.items = {}           # name -> kind
        self.enums = {}           # name -> set(variants)
        self.structs = {}         # name -> set(fields) | None (tuple/unknown)
        self.traits = {}          # name -> {"required": set(), "provided": set()}
        self.reexports = []       # list of (use-path, alias-or-None, line)
        self.fns = {}             # name -> arity (top-level only)


def brace_span(src, open_idx):
    depth = 0
    for j in range(open_idx, len(src)):
        if src[j] == "{":
            depth += 1
        elif src[j] == "}":
            depth -= 1
            if depth == 0:
                return j
    return len(src) - 1


GENERIC_RE = re.compile(r"<[^<>]*>")

def strip_generics(s: str) -> str:
    prev = None
    while prev != s:
        prev = s
        s = GENERIC_RE.sub("", s)
    return s


def split_top(s: str, sep: str = ","):
    s = s.replace("->", "  ").replace("=>", "  ")  # arrows are not generics
    parts, depth, buf = [], 0, []
    for ch in s:
        if ch in "<([{":
            depth += 1
        elif ch in ">)]}":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        parts.append("".join(buf))
    return [p.strip() for p in parts if p.strip()]


def parse_module(path, file, masked):
    m = Module(path, file)
    # enums
    for em in re.finditer(r"(?:pub(?:\(\w+\))?\s+)?enum\s+([A-Za-z_][A-Za-z0-9_]*)\s*(?:<[^{]*>)?\s*\{", masked):
        name = em.group(1)
        close = brace_span(masked, masked.index("{", em.start()))
        body = masked[em.end() : close]
        variants = set()
        for part in split_top(body):
            vm = re.match(r"(?:#\[[^\]]*\]\s*)*([A-Z][A-Za-z0-9_]*)", part.strip())
            if vm:
                variants.add(vm.group(1))
        m.enums[name] = variants
        m.items[name] = "enum"
    # structs with named fields
    for sm in re.finditer(r"(?:pub(?:\(\w+\))?\s+)?struct\s+([A-Za-z_][A-Za-z0-9_]*)\s*(?:<[^;{(]*>)?\s*(\{|\(|;)", masked):
        name, opener = sm.group(1), sm.group(2)
        if opener == "{":
            close = brace_span(masked, masked.index("{", sm.start()))
            body = masked[sm.end() : close]
            fields = set()
            for part in split_top(body):
                fm = re.match(
                    r"(?:#\[[^\]]*\]\s*)*(?:pub(?:\(\w+\))?\s+)?([a-z_][A-Za-z0-9_]*)\s*:",
                    part.strip(),
                )
                if fm:
                    fields.add(fm.group(1))
            m.structs[name] = fields
        else:
            m.structs[name] = None
        m.items[name] = "struct"
    # traits
    for tm in re.finditer(r"(?:pub(?:\(\w+\))?\s+)?trait\s+([A-Za-z_][A-Za-z0-9_]*)[^{;]*\{", masked):
        name = tm.group(1)
        open_idx = masked.index("{", tm.start())
        close = brace_span(masked, open_idx)
        body = masked[open_idx + 1 : close]
        req, prov = set(), set()
        for fm in re.finditer(r"fn\s+([a-z_][A-Za-z0-9_]*)\s*(?:<[^(]*>)?\s*\(", body):
            # does this fn have a body? scan forward for ';' vs '{' at depth 0
            j = fm.end()
            depth = 1  # inside the ( we just matched
            while j < len(body) and depth:
                if body[j] in "([{<":
                    depth += 1
                elif body[j] in ")]}>":
                    depth -= 1
                j += 1
            # after params, skip return type to first ';' or '{'
            while j < len(body) and body[j] not in ";{":
                if body[j] == "<":
                    d2 = 1
                    j += 1
                    while j < len(body) and d2:
                        if body[j] == "<":
                            d2 += 1
                        elif body[j] == ">":
                            d2 -= 1
                        j += 1
                else:
                    j += 1
            (req if j < len(body) and body[j] == ";" else prov).add(fm.group(1))
        m.traits[name] = {"required": req, "provided": prov}
        m.items[name] = "trait"
    # top-level items of remaining kinds
    for im in ITEM_RE.finditer(masked):
        kind, name = im.group(1), im.group(2)
        if kind in ("fn", "const", "static", "type", "union", "macro_rules!"):
            m.items.setdefault(name, kind)
    # re-exports:  pub use x::y::{A, B as C};
    for um in re.finditer(r"^\s*pub\s+use\s+([^;]+);", masked, re.M):
        m.reexports.append((um.group(1).strip(), line_of(masked, um.start())))
    return m


def expand_use(stem: str):
    """Expand `a::b::{C, D as E, self}` into [(path, leaf)] pairs."""
    stem = re.sub(r"\s+", " ", stem)
    out = []
    brace = stem.find("{")
    if brace == -1:
        p = stem
        alias = None
        if " as " in p:
            p, alias = p.split(" as ")
        p = p.strip()
        out.append(p)
        return out
    prefix = stem[:brace].rstrip(": ")
    inner = stem[brace + 1 : stem.rfind("}")]
    for part in split_top(inner):
        if part == "self":
            out.append(prefix)
            continue
        if " as " in part:
            part = part.split(" as ")[0].strip()
        if "{" in part:
            out.extend(expand_use(prefix + "::" + part))
        else:
            out.append(prefix + "::" + part)
    return out


class Crate:
    def __init__(self, root):
        self.root = root
        self.modules = {}  # "a::b" -> Module
        self.findings = []

    def report(self, sev, file, line, msg):
        self.findings.append((sev, file, line, msg))

    def load(self):
        src_root = os.path.join(self.root, "src")
        for dirpath, _dirs, files in os.walk(src_root):
            for f in files:
                if not f.endswith(".rs"):
                    continue
                full = os.path.join(dirpath, f)
                rel = os.path.relpath(full, src_root)
                parts = rel[:-3].split(os.sep)
                if parts[-1] in ("mod", "lib", "main"):
                    parts = parts[:-1]
                mod_path = "::".join(parts)
                with open(full, encoding="utf-8") as fh:
                    src = fh.read()
                masked = mask_source(src)
                mod = parse_module(mod_path, full, masked)
                if mod_path in self.modules:
                    # merge (lib.rs + main.rs both map to "")
                    prev = self.modules[mod_path]
                    prev.items.update(mod.items)
                    prev.enums.update(mod.enums)
                    prev.structs.update(mod.structs)
                    prev.traits.update(mod.traits)
                    prev.reexports.extend(mod.reexports)
                else:
                    self.modules[mod_path] = mod

    # ---- resolution ------------------------------------------------------

    def module_exists(self, path):
        return path in self.modules

    def item_in(self, mod_path, name):
        mod = self.modules.get(mod_path)
        if mod and name in mod.items:
            return True
        # via re-export
        if mod:
            for stem, _ln in mod.reexports:
                for p in expand_use(stem):
                    if p.split("::")[-1] == name:
                        return True
                    if p.endswith("::*"):
                        base = self.norm_crate_path(p[:-3], mod_path)
                        if base and self.item_in(base, name):
                            return True
        return False

    def norm_crate_path(self, p, current_mod=""):
        p = p.strip()
        segs = p.split("::")
        if segs[0] in ("crate", "tod"):
            segs = segs[1:]
        elif segs[0] == "self":
            segs = (current_mod.split("::") if current_mod else []) + segs[1:]
        elif segs[0] == "super":
            base = current_mod.split("::")[:-1] if current_mod else []
            segs = base + segs[1:]
        else:
            return None
        return "::".join(segs)

    def resolve_use(self, full_path, file, line):
        """full_path like scenario::harness::ScenarioHarness (already crate-rooted)."""
        segs = [s for s in full_path.split("::") if s]
        if not segs:
            return
        if segs[0] == "*":
            return  # glob of crate root (or untracked inline module)
        # single-segment path: item in the crate root (lib.rs / re-export)
        if len(segs) == 1 and self.item_in("", segs[0]):
            return
        # longest module prefix
        for cut in range(len(segs), 0, -1):
            prefix = "::".join(segs[:cut])
            if self.module_exists(prefix):
                rest = segs[cut:]
                if not rest:
                    return  # imported a module
                if len(rest) >= 1:
                    name = rest[0]
                    if name == "*":
                        return
                    if self.item_in(prefix, name):
                        # if deeper segs remain it's an enum variant / assoc item; check variant
                        if len(rest) >= 2:
                            mod = self.modules[prefix]
                            if name in mod.enums and rest[1] not in mod.enums[name] and rest[1] != "*":
                                self.report("error", file, line,
                                            f"`{full_path}`: enum `{name}` has no variant `{rest[1]}`")
                        return
                    self.report("error", file, line,
                                f"unresolved import `{full_path}`: no `{name}` in `{prefix or 'crate root'}`")
                    return
        self.report("error", file, line, f"unresolved import `{full_path}`: no such module path")

    def check_uses(self, file, masked, current_mod, crate_names=("crate", "tod")):
        for um in re.finditer(r"^\s*(?:pub\s+)?use\s+([^;]+);", masked, re.M):
            stem = um.group(1)
            ln = line_of(masked, um.start())
            for p in expand_use(stem):
                head = p.split("::")[0]
                if head in crate_names or head in ("self", "super"):
                    norm = self.norm_crate_path(p, current_mod)
                    if norm is not None and norm != "":
                        if norm.endswith("::*"):
                            base = norm[:-3]
                            if not self.module_exists(base):
                                self.report("error", file, ln, f"glob import from missing module `{base}`")
                        else:
                            self.resolve_use(norm, file, ln)

    def all_enum_variants(self):
        d = defaultdict(set)
        for mod in self.modules.values():
            for en, vs in mod.enums.items():
                d[en] |= vs
        return d

    def all_struct_fields(self):
        d = {}
        for mod in self.modules.values():
            for sn, fs in mod.structs.items():
                if fs is None:
                    d[sn] = None  # tuple struct or unknown: never field-check
                elif sn not in d:
                    d[sn] = set(fs)
                elif d[sn] is not None and d[sn] != set(fs):
                    d[sn] = None  # same name, different shape: ambiguous
        return d

    def all_methods(self):
        """Union of every `fn name(` appearing inside any impl/trait block."""
        methods = set()
        for mod in self.modules.values():
            with open(mod.file, encoding="utf-8") as fh:
                masked = mask_source(fh.read())
            for fm in re.finditer(r"fn\s+([a-z_][A-Za-z0-9_]*)\s*(?:<[^(]*>)?\s*\(", masked):
                methods.add(fm.group(1))
        return methods


STD_METHODS = {
    # Option/Result
    "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "expect",
    "ok", "err", "is_ok", "is_err", "is_some", "is_none", "map_err", "and_then",
    "or_else", "ok_or", "ok_or_else", "take", "replace", "as_ref", "as_mut",
    "as_deref", "cloned", "copied", "flatten", "unwrap_err", "get_or_insert_with",
    # iterator
    "iter", "iter_mut", "into_iter", "map", "filter", "filter_map", "fold",
    "sum", "product", "collect", "enumerate", "zip", "chain", "rev", "skip",
    "skip_while", "take_while", "step_by", "flat_map", "find", "find_map",
    "position", "any", "all", "count", "min", "max", "min_by", "max_by",
    "min_by_key", "max_by_key", "last", "nth", "peekable", "peek", "by_ref",
    "windows", "chunks", "chunks_exact", "partition", "unzip", "scan", "cycle",
    "inspect", "copied", "sum_by", "reduce", "try_fold",
    # vec/slice
    "len", "is_empty", "push", "pop", "insert", "remove", "clear", "truncate",
    "extend", "extend_from_slice", "append", "sort", "sort_by", "sort_unstable",
    "sort_by_key", "sort_unstable_by", "sort_unstable_by_key", "dedup",
    "binary_search", "binary_search_by", "partition_point", "split_at",
    "split_first", "split_last", "first", "get", "get_mut", "contains",
    "starts_with", "ends_with", "join", "concat", "to_vec", "swap", "fill",
    "resize", "retain", "drain", "reserve", "reserve_exact", "capacity",
    "with_capacity", "shrink_to_fit", "swap_remove", "rotate_left", "split_off",
    "first_mut", "last_mut", "iter_rows", "as_slice", "as_mut_slice",
    # string
    "to_string", "to_owned", "as_str", "as_bytes", "bytes", "chars", "char_indices",
    "trim", "trim_start", "trim_end", "trim_start_matches", "trim_end_matches",
    "split", "splitn", "rsplitn", "split_whitespace", "split_terminator", "lines",
    "parse", "replace", "replacen", "to_lowercase", "to_uppercase", "repeat",
    "push_str", "strip_prefix", "strip_suffix", "find", "rfind", "matches",
    "eq_ignore_ascii_case", "is_char_boundary",
    # numbers
    "abs", "sqrt", "powi", "powf", "exp", "ln", "log2", "log10", "floor", "ceil",
    "round", "trunc", "fract", "min", "max", "clamp", "is_finite", "is_nan",
    "is_infinite", "is_sign_negative", "is_sign_positive", "signum", "recip",
    "to_bits", "from_bits", "hypot", "mul_add", "rem_euclid", "div_euclid",
    "saturating_add", "saturating_sub", "saturating_mul", "checked_add",
    "checked_sub", "checked_mul", "checked_div", "wrapping_add", "wrapping_sub",
    "wrapping_mul", "overflowing_add", "leading_zeros", "trailing_zeros",
    "count_ones", "pow", "isqrt", "abs_diff", "total_cmp", "partial_cmp",
    "to_le_bytes", "to_be_bytes", "to_ne_bytes",
    # maps/sets
    "entry", "or_insert", "or_insert_with", "or_default", "keys", "values",
    "values_mut", "contains_key", "range", "insert", "remove_entry",
    # misc std
    "clone", "eq", "ne", "cmp", "hash", "fmt", "default", "into", "try_into",
    "from", "try_from", "as_any", "borrow", "borrow_mut", "to_path_buf",
    "display", "exists", "is_file", "is_dir", "extension", "file_name",
    "file_stem", "parent", "components", "read_to_string", "write_all",
    "flush", "read_line", "lock", "try_lock", "read", "write", "send", "recv",
    "try_recv", "recv_timeout", "spawn", "sleep", "elapsed", "as_secs",
    "as_secs_f64", "as_millis", "as_micros", "as_nanos", "from_secs",
    "from_secs_f64", "from_millis", "from_micros", "from_nanos", "duration_since",
    "checked_duration_since", "saturating_duration_since", "now", "wait",
    "wait_timeout", "notify_one", "notify_all", "load", "store", "fetch_add",
    "fetch_sub", "compare_exchange", "swap", "fetch_max", "fetch_min",
    "is_poisoned", "into_inner", "get_ref", "get_many_mut", "join", "thread",
    "id", "name", "panicking", "catch_unwind", "resume_unwind", "downcast",
    "downcast_ref", "downcast_mut", "is", "type_id", "to_ascii_lowercase",
    "to_ascii_uppercase", "make_ascii_lowercase", "is_ascii_digit",
    "is_ascii_alphanumeric", "is_ascii_alphabetic", "is_ascii_whitespace",
    "is_ascii_uppercase", "is_ascii_lowercase", "is_alphabetic", "is_numeric",
    "is_alphanumeric", "is_whitespace", "is_uppercase", "is_lowercase",
    "to_digit", "next", "next_back", "rem", "div", "mul", "add", "sub", "neg",
    "not", "bitand", "bitor", "bitxor", "shl", "shr", "index", "index_mut",
    "deref", "deref_mut", "drop", "finish", "debug_struct", "debug_tuple",
    "debug_list", "debug_map", "field", "key", "value", "args", "var",
    "current_dir", "temp_dir", "create_dir_all", "remove_file", "remove_dir_all",
    "read_dir", "metadata", "canonicalize", "set_extension", "with_extension",
    "to_str", "to_string_lossy", "as_os_str", "into_os_string", "success",
    "code", "status", "stdout", "stderr", "stdin", "output", "arg", "env",
}


def check_enum_refs(crate, file, masked, variants_by_enum, items_global):
    """Check Path::Variant references where Path is a known enum."""
    for rm in re.finditer(r"\b([A-Z][A-Za-z0-9_]*)::([A-Za-z_][A-Za-z0-9_]*)\b", masked):
        en, member = rm.group(1), rm.group(2)
        if en in variants_by_enum:
            vs = variants_by_enum[en]
            if member in vs:
                continue
            # assoc fn/const on the enum? allow lowercase or SCREAMING or known-fn heuristics
            if not member[0].isupper():
                continue  # assoc fn
            if member.isupper():
                continue  # assoc const
            if member in ("Output", "Item", "Err", "Ok"):
                continue
            crate.report("error", file, line_of(masked, rm.start()),
                         f"enum `{en}` has no variant `{member}`")


STD_STRUCT_WHITELIST = {
    "Some", "Ok", "Err", "None", "Box", "Vec", "String", "Duration", "Range",
    "RangeInclusive", "Instant", "PathBuf", "HashMap", "BTreeMap", "HashSet",
    "BTreeSet", "VecDeque", "Ordering", "Self",
}


def check_struct_literals(crate, file, masked, fields_by_struct):
    """`Name { field: v, .. }` — flag unknown field names (skip ..-spread unknown)."""
    for sm in re.finditer(r"\b([A-Z][A-Za-z0-9_]*)\s*\{", masked):
        name = sm.group(1)
        if name in STD_STRUCT_WHITELIST or name not in fields_by_struct:
            continue
        known = fields_by_struct[name]
        if known is None:
            continue
        open_idx = masked.index("{", sm.start())
        # exclude match arms / blocks: struct literal heuristics — preceding
        # non-space char should not be ')' '>' 'else' etc. Keep simple: check
        # the body looks like `ident:` pairs or `..`.
        close = brace_span(masked, open_idx)
        body = masked[open_idx + 1 : close]
        # only treat as literal if first token is `ident:` or `ident,` or `..`
        probe = body.strip()
        if not re.match(r"^(\.\.|[a-z_][A-Za-z0-9_]*\s*[:,}])", probe) and probe != "":
            continue
        for fm in re.finditer(r"(?:^|,)\s*([a-z_][A-Za-z0-9_]*)\s*(?=[:,}])", body):
            fname = fm.group(1)
            # shorthand or explicit — both must be real fields
            if fname not in known:
                crate.report("advisory", file, line_of(masked, open_idx),
                             f"struct `{name}` has no field `{fname}` (pattern or literal)")


IMPL_RE = re.compile(
    r"^\s*impl(?:\s*<[^>]*>)?\s+(?:([A-Za-z_][A-Za-z0-9_]*(?:::[A-Za-z_][A-Za-z0-9_]*)*)\s*(?:<[^>]*>)?\s+for\s+)?"
    r"([A-Za-z_][A-Za-z0-9_]*)",
    re.M,
)

FN_RE = re.compile(r"\bfn\s+([a-z_][A-Za-z0-9_]*)\s*(?:<[^(]*>)?\s*\(")


def paren_span(s, open_idx):
    depth = 0
    for j in range(open_idx, len(s)):
        if s[j] in "([{":
            depth += 1
        elif s[j] in ")]}":
            depth -= 1
            if depth == 0:
                return j
    return len(s) - 1


def fn_arity(params: str):
    """(has_self, n_args) from a raw parameter list."""
    parts = split_top(params)
    has_self = bool(parts) and ("self" == parts[0].split(":")[0].strip().split()[-1].lstrip("&").strip()
                                or parts[0].strip() in ("self", "&self", "&mut self", "mut self"))
    if has_self:
        parts = parts[1:]
    return has_self, len(parts)


def collect_impls(masked):
    """Yield (trait_name_or_None, type_name, {method: (has_self, arity)})."""
    out = []
    for im in IMPL_RE.finditer(masked):
        trait_name, type_name = im.group(1), im.group(2)
        brace = masked.find("{", im.end())
        semi = masked.find(";", im.end())
        if brace == -1 or (semi != -1 and semi < brace):
            continue
        close = brace_span(masked, brace)
        body = masked[brace + 1 : close]
        methods = {}
        for fm in FN_RE.finditer(body):
            p_open = body.index("(", fm.end() - 1)
            p_close = paren_span(body, p_open)
            methods[fm.group(1)] = fn_arity(body[p_open + 1 : p_close])
        out.append((trait_name, type_name, methods, line_of(masked, im.start())))
    return out


def check_trait_impls(crate, file, masked, traits_by_name):
    for trait_name, type_name, methods, ln in collect_impls(masked):
        if not trait_name:
            continue
        tshort = trait_name.split("::")[-1]
        td = traits_by_name.get(tshort)
        if td is None:
            continue  # std trait (Display, Drop, ...) or unknown
        allowed = td["required"] | td["provided"]
        for m in methods:
            if m not in allowed:
                crate.report("error", file, ln,
                             f"impl {tshort} for {type_name}: `{m}` is not a member of the trait")
        missing = td["required"] - set(methods)
        if missing:
            crate.report("error", file, ln,
                         f"impl {tshort} for {type_name}: missing required method(s) {sorted(missing)}")


ARM_CATCHALL_RE = re.compile(r"^\s*(_|[a-z_][A-Za-z0-9_]*)\s*$")
HEAD_ENUM_RE = re.compile(r"\b([A-Z][A-Za-z0-9_]*)::([A-Z][A-Za-z0-9_]*)")

STD_ENUMS = {"Option", "Result", "Ordering", "Bound", "Cow", "Entry", "ControlFlow"}


def match_arms(body):
    """Split a match body into (head, has_more) arm heads at depth 0."""
    heads = []
    i, n = 0, len(body)
    while i < n:
        # collect head up to => at depth 0
        depth = 0
        start = i
        while i < n:
            c = body[i]
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
            elif depth == 0 and body[i : i + 2] == "=>":
                break
            i += 1
        if i >= n:
            break
        head = body[start:i].strip()
        heads.append(head)
        i += 2
        # skip arm body
        while i < n and body[i] in " \t\n":
            i += 1
        if i < n and body[i] == "{":
            i = brace_span(body, i) + 1
            if i < n and body[i : i + 1] == ",":
                i += 1
        else:
            depth = 0
            while i < n:
                c = body[i]
                if c in "([{":
                    depth += 1
                elif c in ")]}":
                    depth -= 1
                elif c == "," and depth == 0:
                    i += 1
                    break
                i += 1
    return heads


def check_match_exhaustiveness(crate, file, masked, variants_by_enum):
    for mm in re.finditer(r"\bmatch\b", masked):
        brace = masked.find("{", mm.end())
        if brace == -1:
            continue
        # guard against `match` in idents (premasked word boundary ok) and
        # matches! macro (masked keeps `matches!` text: the ! precedes `(`)
        close = brace_span(masked, brace)
        body = masked[brace + 1 : close]
        heads = match_arms(body)
        if not heads:
            continue
        seen = defaultdict(set)
        catchall = False
        enums_in_heads = []
        ok = True
        for head in heads:
            head_nog = head.split(" if ")[0]
            if ARM_CATCHALL_RE.match(head_nog) or ".." in head_nog and "{" not in head_nog and "(" not in head_nog:
                catchall = True
                continue
            refs = HEAD_ENUM_RE.findall(head_nog)
            top = [r for r in refs if r[0] not in STD_ENUMS]
            if not refs:
                # literal / tuple / binding-with-struct pattern — bail out
                ok = False
                break
            if not top:
                ok = False  # std-enum match; rustc handles, skip
                break
            first = top[0]
            enums_in_heads.append(first[0])
            for en, v in top:
                if en == first[0]:
                    seen[en].add(v)
        if not ok or catchall or not enums_in_heads:
            continue
        if len(set(enums_in_heads)) != 1:
            continue
        en = enums_in_heads[0]
        known = variants_by_enum.get(en)
        if not known:
            continue
        missing = known - seen[en]
        # variants referenced that don't exist are caught elsewhere; here only missing
        if missing and seen[en] <= known:
            crate.report("error", file, line_of(masked, mm.start()),
                         f"match on `{en}` missing variant(s) {sorted(missing)} and no catch-all arm")


def build_method_signatures(crate):
    """name -> set of (has_self, arity) across every impl block in src."""
    sigs = defaultdict(set)
    for mod in crate.modules.values():
        with open(mod.file, encoding="utf-8") as fh:
            masked = mask_source(fh.read())
        for _tr, _ty, methods, _ln in collect_impls(masked):
            for name, sig in methods.items():
                sigs[name].add(sig)
    return sigs


def call_arg_count(orig_inner: str, masked_inner: str) -> int:
    """Top-level commas from masked text, segment emptiness from original."""
    commas = []
    depth = 0
    for i, ch in enumerate(masked_inner):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            commas.append(i)
    count = 0
    for a, b in zip([0] + [c + 1 for c in commas], commas + [len(orig_inner)]):
        masked_seg = masked_inner[a:b]
        orig_seg = orig_inner[a:b]
        if masked_seg.strip():
            count += 1
        elif ('"' in orig_seg or "'" in orig_seg) and orig_seg.strip():
            count += 1  # a lone string/char literal, blanked by masking
    return count


def check_call_arity(crate, file, src, masked, sigs):
    for cm in re.finditer(r"\.([a-z_][A-Za-z0-9_]*)\s*\(", masked):
        name = cm.group(1)
        if name in STD_METHODS or name not in sigs or len(sigs[name]) != 1:
            continue
        ((has_self, arity),) = sigs[name]
        if not has_self:
            continue
        p_open = masked.index("(", cm.end() - 1)
        p_close = paren_span(masked, p_open)
        call_arity = call_arg_count(src[p_open + 1 : p_close], masked[p_open + 1 : p_close])
        if call_arity != arity:
            crate.report("advisory", file, line_of(masked, cm.start()),
                         f"call `.{name}(…)` passes {call_arity} arg(s); sole crate "
                         f"definition takes {arity}")


def build_assoc_signatures(crate):
    """(type, fn) -> set of (has_self, arity); also enum tuple-variant arity."""
    sigs = defaultdict(set)
    for mod in crate.modules.values():
        with open(mod.file, encoding="utf-8") as fh:
            masked = mask_source(fh.read())
        for _tr, ty, methods, _ln in collect_impls(masked):
            for name, sig in methods.items():
                sigs[(ty, name)].add(sig)
    return sigs


def build_variant_arity(crate):
    """(enum, Variant) -> arity for tuple variants; -1 for struct/unit."""
    out = {}
    for mod in crate.modules.values():
        with open(mod.file, encoding="utf-8") as fh:
            masked = mask_source(fh.read())
        for em in re.finditer(
            r"(?:pub(?:\(\w+\))?\s+)?enum\s+([A-Za-z_][A-Za-z0-9_]*)\s*(?:<[^{]*>)?\s*\{", masked
        ):
            name = em.group(1)
            close = brace_span(masked, masked.index("{", em.start()))
            body = masked[em.end() : close]
            for part in split_top(body):
                part = part.strip()
                vm = re.match(r"(?:#\[[^\]]*\]\s*)*([A-Z][A-Za-z0-9_]*)\s*(\(|\{|=|$)", part)
                if not vm:
                    continue
                vname, opener = vm.group(1), vm.group(2)
                if opener == "(":
                    p_open = part.index("(", vm.end() - 1)
                    p_close = paren_span(part, p_open)
                    out[(name, vname)] = len(split_top(part[p_open + 1 : p_close]))
                else:
                    out[(name, vname)] = -1
    return out


def check_assoc_calls(crate, file, src, masked, assoc_sigs, variant_arity, enums):
    """`Type::func(args)` arity for unique crate definitions; tuple-variant arity."""
    for cm in re.finditer(r"\b([A-Z][A-Za-z0-9_]*)::([A-Za-z_][A-Za-z0-9_]*)\s*\(", masked):
        ty, name = cm.group(1), cm.group(2)
        p_open = masked.index("(", cm.end() - 1)
        p_close = paren_span(masked, p_open)
        call_arity = call_arg_count(src[p_open + 1 : p_close], masked[p_open + 1 : p_close])
        if ty in enums and name[0].isupper():
            want = variant_arity.get((ty, name))
            if want is not None and want >= 0 and call_arity != want:
                crate.report("error", file, line_of(masked, cm.start()),
                             f"`{ty}::{name}` takes {want} value(s); constructed with {call_arity}")
            continue
        if name[0].isupper():
            continue
        key = (ty, name)
        if key not in assoc_sigs or len(assoc_sigs[key]) != 1:
            continue
        ((has_self, arity),) = assoc_sigs[key]
        want = arity + (1 if has_self else 0)  # UFCS passes the receiver
        ok = call_arity == arity or (has_self and call_arity == want)
        if not ok:
            crate.report("advisory", file, line_of(masked, cm.start()),
                         f"call `{ty}::{name}(…)` passes {call_arity} arg(s); "
                         f"definition takes {arity}{' (+self)' if has_self else ''}")


CONFIDENT_LIT_PREFIX = re.compile(r"(=|\(|,|\[|return|\bSome\(|\bOk\(|\bErr\(|=>|\.push\(|\bBox::new\()\s*$")


def check_struct_literal_completeness(crate, file, masked, crate_struct_fields):
    """E0063: literal without `..` base must name every field."""
    for sm in re.finditer(r"\b([A-Z][A-Za-z0-9_]*)\s*\{", masked):
        name = sm.group(1)
        fields = crate_struct_fields.get(name)
        if not fields:
            continue
        prefix = masked[max(0, sm.start() - 24) : sm.start()]
        if not CONFIDENT_LIT_PREFIX.search(prefix):
            continue
        open_idx = masked.index("{", sm.start())
        close = brace_span(masked, open_idx)
        body = masked[open_idx + 1 : close]
        if ".." in re.sub(r"\.\.[=.]", "", body):
            continue  # functional-update base (mask range ops crudely)
        named = set()
        bad = False
        for part in split_top(body):
            fm = re.match(r"^([a-z_][A-Za-z0-9_]*)\s*(?::|$)", part.strip())
            if fm:
                named.add(fm.group(1))
            else:
                bad = True  # not a plain literal after all (e.g. a block)
        if bad or not named:
            continue
        missing = fields - named
        extra = named - fields
        if extra:
            continue  # probably a pattern or shadowed-name false positive
        if missing:
            crate.report("error", file, line_of(masked, sm.start()),
                         f"literal `{name} {{…}}` missing field(s) {sorted(missing)} with no `..` base")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="rust")
    ap.add_argument("--advisory", action="store_true", help="print advisory findings too")
    args = ap.parse_args()

    crate = Crate(args.root)
    crate.load()

    variants = crate.all_enum_variants()
    fields = crate.all_struct_fields()
    methods = crate.all_methods()
    sigs = build_method_signatures(crate)
    assoc_sigs = build_assoc_signatures(crate)
    variant_arity = build_variant_arity(crate)
    traits_by_name = {}
    for mod in crate.modules.values():
        traits_by_name.update(mod.traits)

    # check src files
    for mod in sorted(crate.modules.values(), key=lambda m: m.file):
        with open(mod.file, encoding="utf-8") as fh:
            src_text = fh.read()
        masked = mask_source(src_text)
        crate.check_uses(mod.file, masked, mod.path)
        check_enum_refs(crate, mod.file, masked, variants, None)
        check_trait_impls(crate, mod.file, masked, traits_by_name)
        check_match_exhaustiveness(crate, mod.file, masked, variants)
        check_call_arity(crate, mod.file, src_text, masked, sigs)
        check_assoc_calls(crate, mod.file, src_text, masked, assoc_sigs, variant_arity, variants)
        check_struct_literal_completeness(crate, mod.file, masked, fields)

    # tests / benches / examples: `use tod::...`
    extra = []
    for sub in ("tests", "benches"):
        d = os.path.join(args.root, sub)
        if os.path.isdir(d):
            for f in sorted(os.listdir(d)):
                if f.endswith(".rs"):
                    extra.append(os.path.join(d, f))
    exdir = os.path.join(os.path.dirname(args.root) or ".", "examples")
    if os.path.isdir(exdir):
        for f in sorted(os.listdir(exdir)):
            if f.endswith(".rs"):
                extra.append(os.path.join(exdir, f))
    for file in extra:
        with open(file, encoding="utf-8") as fh:
            src_text = fh.read()
        masked = mask_source(src_text)
        crate.check_uses(file, masked, "")
        check_enum_refs(crate, file, masked, variants, None)
        check_trait_impls(crate, file, masked, traits_by_name)
        check_match_exhaustiveness(crate, file, masked, variants)
        check_call_arity(crate, file, src_text, masked, sigs)
        check_assoc_calls(crate, file, src_text, masked, assoc_sigs, variant_arity, variants)
        check_struct_literal_completeness(crate, file, masked, fields)
        # method-existence probe
        for mm in re.finditer(r"\.([a-z_][A-Za-z0-9_]*)\s*\(", masked):
            name = mm.group(1)
            if name not in methods and name not in STD_METHODS:
                crate.report("advisory", file, line_of(masked, mm.start()),
                             f"method `.{name}()` not found in any impl block (may be std)")

    errors = [f for f in crate.findings if f[0] == "error"]
    advisories = [f for f in crate.findings if f[0] != "error"]
    shown = crate.findings if args.advisory else errors
    for sev, file, line, msg in sorted(shown, key=lambda t: (t[1], t[2])):
        print(f"{sev}: {file}:{line}: {msg}")
    print(f"\n{len(errors)} error(s), {len(advisories)} advisory finding(s) "
          f"across {len(crate.modules)} modules")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
