//! The curated scenario matrix: eight named edge workloads.
//!
//! Every scenario stresses a different axis of the deployment space the
//! single-sequence MOT17 catalog cannot reach (AyE-Edge's argument in
//! PAPERS.md): regime *shifts* mid-stream, day/night noise, capture-
//! clock sag/burst, camera handoffs, stream churn and power squeezes.
//! Each scenario is built so that no single fixed DNN is right in every
//! phase — a phase with small far-field boxes punishes the light nets
//! (capacity), a phase with large fast-moving boxes punishes the heavy
//! nets (drops + stale carried detections) — which is what the
//! differential layer in [`super::conformance`] pins: adaptive
//! selection must never lose to the best fixed DNN on any scenario.
//! The matrix is the regression backbone: `tod scenario check` replays
//! all eight against the goldens in `rust/tests/goldens/`.

use crate::dataset::synth::CameraMotion;

use super::spec::{NoiseProfile, PhaseSpec, ScenarioSpec, StreamSpec};

/// Identifier for the eight curated scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ScenarioId {
    RushHourSurge,
    NightDrift,
    FpsSag,
    CameraHandoff,
    StreamChurn,
    BudgetSqueeze,
    BurstyCrowd,
    SteadySparse,
}

impl ScenarioId {
    /// All scenarios, in matrix order.
    pub const ALL: [ScenarioId; 8] = [
        ScenarioId::RushHourSurge,
        ScenarioId::NightDrift,
        ScenarioId::FpsSag,
        ScenarioId::CameraHandoff,
        ScenarioId::StreamChurn,
        ScenarioId::BudgetSqueeze,
        ScenarioId::BurstyCrowd,
        ScenarioId::SteadySparse,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ScenarioId::RushHourSurge => "rush-hour-surge",
            ScenarioId::NightDrift => "night-drift",
            ScenarioId::FpsSag => "fps-sag",
            ScenarioId::CameraHandoff => "camera-handoff",
            ScenarioId::StreamChurn => "stream-churn",
            ScenarioId::BudgetSqueeze => "budget-squeeze",
            ScenarioId::BurstyCrowd => "bursty-crowd",
            ScenarioId::SteadySparse => "steady-sparse",
        }
    }
}

impl std::fmt::Display for ScenarioId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ScenarioId {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ScenarioId::ALL
            .iter()
            .find(|id| id.name() == s)
            .copied()
            .ok_or_else(|| {
                let names: Vec<&str> =
                    ScenarioId::ALL.iter().map(|id| id.name()).collect();
                format!(
                    "unknown scenario: {s:?} (want one of {})",
                    names.join("|")
                )
            })
    }
}

/// Build the spec of one matrix scenario.
pub fn scenario_spec(id: ScenarioId) -> ScenarioSpec {
    match id {
        // A hand-carried plaza camera through rush hour: sparse close
        // walkers under a brisk pan (staleness punishes the heavy
        // nets), then a dense far-field surge from a parked position
        // (capacity punishes the light nets), then the tail.
        ScenarioId::RushHourSurge => ScenarioSpec::new(
            "rush-hour-surge",
            "sparse close walkers under a pan -> dense far-field surge \
             -> tail; the size regime flips twice",
            vec![StreamSpec::new(
                "plaza",
                vec![
                    PhaseSpec::new("calm", 140)
                        .density(5)
                        .ref_height(430.0)
                        .depth_range(1.0, 1.8)
                        .walk_speed(1.4)
                        .camera(CameraMotion::Walking { pan_speed: 16.0 }),
                    PhaseSpec::new("surge", 160)
                        .density(24)
                        .ref_height(150.0)
                        .depth_range(1.6, 3.0)
                        .walk_speed(2.0),
                    PhaseSpec::new("ease", 100)
                        .density(9)
                        .ref_height(320.0)
                        .depth_range(1.1, 2.0)
                        .walk_speed(1.6)
                        .camera(CameraMotion::Walking { pan_speed: 12.0 }),
                ],
            )])
            .seed(0x51de_0001),

        // A mast camera by day (far field, small boxes), handed to a
        // patrol bodycam at night (close crowd, fast pan) while
        // detection noise ramps through dusk into night.
        ScenarioId::NightDrift => ScenarioSpec::new(
            "night-drift",
            "far-field day watch -> dusk -> close night patrol under \
             ramping detection noise",
            vec![StreamSpec::new(
                "watch",
                vec![
                    PhaseSpec::new("day", 150)
                        .density(12)
                        .ref_height(180.0)
                        .depth_range(1.4, 2.8),
                    PhaseSpec::new("dusk", 100)
                        .density(10)
                        .ref_height(300.0)
                        .depth_range(1.2, 2.2)
                        .camera(CameraMotion::Walking { pan_speed: 10.0 })
                        .noise(NoiseProfile { miss: 0.12, conf_loss: 0.1 }),
                    PhaseSpec::new("night", 150)
                        .density(7)
                        .ref_height(480.0)
                        .depth_range(1.0, 1.8)
                        .camera(CameraMotion::Walking { pan_speed: 14.0 })
                        .noise(NoiseProfile::NIGHT),
                ],
            )])
            .seed(0x51de_0002),

        // The capture clock misbehaves: a nominal small-object feed, a
        // sag to ~0.55x (heavy nets suddenly affordable), then a
        // backlog burst at 1.35x on a flipped large-fast regime where
        // every extra millisecond costs dropped frames.
        ScenarioId::FpsSag => ScenarioSpec::new(
            "fps-sag",
            "nominal -> camera sags to ~0.55x -> backlog burst at 1.35x \
             on a flipped size regime",
            vec![StreamSpec::new(
                "feed",
                vec![
                    PhaseSpec::new("nominal", 120)
                        .density(10)
                        .ref_height(140.0)
                        .depth_range(1.4, 2.8),
                    PhaseSpec::new("sag", 120)
                        .density(10)
                        .ref_height(140.0)
                        .depth_range(1.4, 2.8)
                        .fps_scale(0.55),
                    PhaseSpec::new("burst", 120)
                        .density(7)
                        .ref_height(420.0)
                        .depth_range(1.0, 1.8)
                        .camera(CameraMotion::Walking { pan_speed: 20.0 })
                        .fps_scale(1.35),
                ],
            )])
            .seed(0x51de_0003),

        // One logical feed handed between three physically different
        // cameras: fixed mast (small static), vehicle dashcam (mid,
        // fast flow), handheld close-up (large, fast pan).
        ScenarioId::CameraHandoff => ScenarioSpec::new(
            "camera-handoff",
            "mast camera -> vehicle dashcam -> handheld close-up; all \
             three motion classes in one stream",
            vec![StreamSpec::new(
                "relay",
                vec![
                    PhaseSpec::new("mast", 130)
                        .density(14)
                        .ref_height(170.0)
                        .depth_range(1.4, 2.8),
                    PhaseSpec::new("dashcam", 130)
                        .density(10)
                        .ref_height(250.0)
                        .walk_speed(2.2)
                        .camera(CameraMotion::Vehicle { flow_speed: 16.0 }),
                    PhaseSpec::new("handheld", 130)
                        .density(7)
                        .ref_height(520.0)
                        .depth_range(1.0, 1.8)
                        .camera(CameraMotion::Walking { pan_speed: 26.0 }),
                ],
            )])
            .seed(0x51de_0004),

        // Cameras come and go on one accelerator: a steady walker from
        // t=0, a dashcam joining at 2 s, a dense far-field crowd camera
        // joining at 4 s; every stream leaves when its footage ends.
        ScenarioId::StreamChurn => ScenarioSpec::new(
            "stream-churn",
            "three cameras join staggered on one accelerator and leave \
             when their footage ends",
            vec![
                StreamSpec::new(
                    "steady",
                    vec![PhaseSpec::new("walk", 220)
                        .density(8)
                        .ref_height(320.0)
                        .depth_range(1.0, 2.0)
                        .camera(CameraMotion::Walking { pan_speed: 10.0 })],
                ),
                StreamSpec::new(
                    "dashcam",
                    vec![PhaseSpec::new("drive", 180)
                        .density(10)
                        .ref_height(240.0)
                        .camera(CameraMotion::Vehicle { flow_speed: 14.0 })],
                )
                .join_at(2.0),
                StreamSpec::new(
                    "crowd",
                    vec![PhaseSpec::new("dense", 160)
                        .density(18)
                        .ref_height(170.0)
                        .depth_range(1.4, 2.6)],
                )
                .join_at(4.0),
            ],
        )
        .seed(0x51de_0005),

        // Small far-field objects pull selection onto the heavy nets
        // exactly when the board cap is tightest: the budgeted
        // configurations must hold 5.8 W through the squeeze while the
        // ungoverned ladder runs hot.
        ScenarioId::BudgetSqueeze => ScenarioSpec::new(
            "budget-squeeze",
            "a small-object squeeze phase demands the heavy nets while \
             the board cap sits at 5.8 W",
            vec![StreamSpec::new(
                "gate",
                vec![
                    PhaseSpec::new("lean", 120)
                        .density(8)
                        .ref_height(330.0)
                        .depth_range(1.0, 2.0)
                        .camera(CameraMotion::Walking { pan_speed: 10.0 }),
                    PhaseSpec::new("squeeze", 160)
                        .density(12)
                        .ref_height(140.0)
                        .depth_range(1.4, 2.8),
                    PhaseSpec::new("relax", 100)
                        .density(6)
                        .ref_height(380.0)
                        .depth_range(1.0, 1.9)
                        .camera(CameraMotion::Walking { pan_speed: 8.0 }),
                ],
            )])
            .seed(0x51de_0006)
            .watts_budget(5.8),

        // The crowd flaps: close-up lulls under an operator pan
        // alternating with dense far-field bursts — the light-net and
        // heavy-net regimes swap every three seconds.
        ScenarioId::BurstyCrowd => ScenarioSpec::new(
            "bursty-crowd",
            "lull/burst/lull/burst crowd flapping between the light-net \
             and heavy-net regimes",
            vec![StreamSpec::new(
                "court",
                vec![
                    PhaseSpec::new("lull1", 90)
                        .density(4)
                        .ref_height(420.0)
                        .depth_range(1.0, 1.8)
                        .camera(CameraMotion::Walking { pan_speed: 12.0 }),
                    PhaseSpec::new("burst1", 90)
                        .density(22)
                        .ref_height(160.0)
                        .depth_range(1.5, 2.9),
                    PhaseSpec::new("lull2", 90)
                        .density(4)
                        .ref_height(420.0)
                        .depth_range(1.0, 1.8)
                        .camera(CameraMotion::Walking { pan_speed: 12.0 }),
                    PhaseSpec::new("burst2", 90)
                        .density(22)
                        .ref_height(160.0)
                        .depth_range(1.5, 2.9),
                ],
            )])
            .seed(0x51de_0007),

        // The near-control: a short far-field approach, then one long
        // steady sparse phase of large fast walkers where the lightest
        // net is the clear winner — adaptive selection must settle
        // there and stay, not churn.
        ScenarioId::SteadySparse => ScenarioSpec::new(
            "steady-sparse",
            "short far-field approach, then a long steady sparse phase \
             of large fast walkers",
            vec![StreamSpec::new(
                "lane",
                vec![
                    PhaseSpec::new("approach", 80)
                        .density(10)
                        .ref_height(150.0)
                        .depth_range(1.4, 2.8),
                    PhaseSpec::new("steady", 320)
                        .density(3)
                        .ref_height(450.0)
                        .depth_range(1.0, 1.6)
                        .camera(CameraMotion::Walking { pan_speed: 18.0 }),
                ],
            )])
            .seed(0x51de_0008),
    }
}

/// Build the full matrix, in [`ScenarioId::ALL`] order.
pub fn matrix() -> Vec<ScenarioSpec> {
    ScenarioId::ALL.iter().map(|&id| scenario_spec(id)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_scenarios_validate_and_compile() {
        for id in ScenarioId::ALL {
            let spec = scenario_spec(id);
            assert_eq!(spec.name, id.name());
            assert!(!spec.description.is_empty());
            spec.validate().unwrap_or_else(|e| panic!("{id}: {e}"));
            let streams = spec.compile().unwrap();
            assert_eq!(streams.len(), spec.streams.len());
        }
    }

    #[test]
    fn names_parse_back() {
        for id in ScenarioId::ALL {
            assert_eq!(id.name().parse::<ScenarioId>().unwrap(), id);
        }
        assert!("mystery-scene".parse::<ScenarioId>().is_err());
    }

    #[test]
    fn matrix_names_and_seeds_are_unique() {
        let specs = matrix();
        assert_eq!(specs.len(), 8);
        let names: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names.len(), specs.len());
        let seeds: std::collections::BTreeSet<u64> =
            specs.iter().map(|s| s.seed).collect();
        assert_eq!(seeds.len(), specs.len());
    }

    #[test]
    fn matrix_covers_the_deployment_axes() {
        let specs = matrix();
        // at least one scenario with noise, one with fps_scale on both
        // sides of 1, one with churn (join > 0), one with a non-default
        // watts cap, and all three camera classes somewhere
        let phases =
            || specs.iter().flat_map(|s| &s.streams).flat_map(|s| &s.phases);
        assert!(phases().any(|p| !p.noise.is_clean()));
        assert!(phases().any(|p| p.fps_scale < 1.0));
        assert!(phases().any(|p| p.fps_scale > 1.0));
        assert!(specs
            .iter()
            .flat_map(|s| &s.streams)
            .any(|s| s.join_s > 0.0));
        assert!(specs
            .iter()
            .any(|s| s.watts_budget != crate::app::DEFAULT_WATTS_BUDGET));
        assert!(phases().any(|p| matches!(p.camera, CameraMotion::Static)));
        assert!(phases()
            .any(|p| matches!(p.camera, CameraMotion::Walking { .. })));
        assert!(phases()
            .any(|p| matches!(p.camera, CameraMotion::Vehicle { .. })));
        // multi-phase regime shifts are the point: most scenarios have
        // more than one phase
        let shifting = specs
            .iter()
            .filter(|s| s.streams.iter().any(|st| st.phases.len() > 1))
            .count();
        assert!(shifting >= 5, "only {shifting} scenarios shift regimes");
    }

    #[test]
    fn every_scenario_mixes_light_and_heavy_regimes() {
        // the differential layer's premise: each scenario must contain
        // both a large-object regime (light nets suffice) and a
        // small-object regime (capacity matters), across its phases or
        // streams — except that multi-stream scenarios may split the
        // regimes across streams. Nominal MBBS proxies: ref_height at
        // mid depth as an area fraction of the 960x540 frame.
        for spec in matrix() {
            let mut fracs = Vec::new();
            for stream in &spec.streams {
                for p in &stream.phases {
                    let d = (p.depth_range.0 + p.depth_range.1) / 2.0;
                    let h = p.ref_height / d;
                    let frac = (h * h * 0.41)
                        / (spec.width as f64 * spec.height as f64);
                    fracs.push(frac);
                }
            }
            let max = fracs.iter().cloned().fold(0.0f64, f64::max);
            let min = fracs.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                max > 0.03,
                "{}: no large-object regime (max nominal MBBS {max})",
                spec.name
            );
            assert!(
                min < 0.012,
                "{}: no small-object regime (min nominal MBBS {min})",
                spec.name
            );
        }
    }
}
