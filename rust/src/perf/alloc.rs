//! Counting global allocator: allocs/op and bytes/op measurement.
//!
//! The crate installs [`CountingAllocator`] as the `#[global_allocator]`
//! (see `lib.rs`), so every heap allocation made by the process bumps a
//! thread-local counter on its way to the system allocator. The counters
//! are per-thread, which makes [`count_allocs`] deterministic even when
//! other threads (e.g. the exec thread pool) allocate concurrently:
//! a span only observes its own thread's allocations.
//!
//! Deallocations are deliberately *not* tracked — the bench suite gates
//! on "new allocations per operation" (a steady-state hot path must not
//! touch the allocator at all), not on net live bytes.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

/// Thin wrapper around [`System`] that counts allocations per thread.
///
/// `realloc` counts as one allocation (growing a `Vec` in place still
/// round-trips through the allocator), `dealloc` counts as none.
pub struct CountingAllocator;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn bump(bytes: usize) {
    // try_with: allocations during TLS teardown must not abort.
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    let _ = BYTES.try_with(|c| c.set(c.get() + bytes as u64));
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        bump(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations made by the current thread since it started.
pub fn thread_allocs() -> u64 {
    ALLOCS.try_with(Cell::get).unwrap_or(0)
}

/// Total bytes requested by the current thread since it started.
pub fn thread_alloc_bytes() -> u64 {
    BYTES.try_with(Cell::get).unwrap_or(0)
}

/// Allocation counts over one closure call on the current thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocDelta {
    pub allocs: u64,
    pub bytes: u64,
}

/// Run `f` and report how many allocations it performed on this thread.
pub fn count_allocs<R>(f: impl FnOnce() -> R) -> (AllocDelta, R) {
    let a0 = thread_allocs();
    let b0 = thread_alloc_bytes();
    let out = f();
    let delta = AllocDelta {
        allocs: thread_allocs() - a0,
        bytes: thread_alloc_bytes() - b0,
    };
    (delta, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_growth_is_counted() {
        let (d, v) = count_allocs(|| {
            let mut v: Vec<u64> = Vec::with_capacity(4);
            v.extend_from_slice(&[1, 2, 3]);
            v
        });
        assert!(d.allocs >= 1, "with_capacity must hit the allocator");
        assert!(d.bytes >= 32);
        drop(v);
    }

    #[test]
    fn pure_arithmetic_is_alloc_free() {
        let (d, s) = count_allocs(|| (0..1000u64).map(|x| x * x).sum::<u64>());
        assert_eq!(d.allocs, 0, "closure must not allocate");
        assert_eq!(s, 332_833_500);
    }

    #[test]
    fn reused_buffer_is_alloc_free_after_warmup() {
        let mut buf: Vec<f64> = Vec::new();
        // warm the buffer up to its steady-state capacity
        buf.extend((0..256).map(|i| i as f64));
        let (d, _) = count_allocs(|| {
            buf.clear();
            buf.extend((0..256).map(|i| i as f64 * 2.0));
            buf.len()
        });
        assert_eq!(d.allocs, 0, "clear+refill within capacity allocates");
    }
}
