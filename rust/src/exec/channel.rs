//! Bounded MPMC channel with blocking send (backpressure) built on
//! `Mutex` + `Condvar`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

struct Shared<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> Shared<T> {
    /// Lock the state, recovering from a poisoned mutex: a peer that
    /// panicked elsewhere must not cascade a panic into every channel
    /// user. The state stays consistent under poisoning because each
    /// critical section finishes its counter/queue bookkeeping before
    /// running any code that can panic.
    fn lock(&self) -> MutexGuard<'_, State<T>> {
        self.queue.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

struct State<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

/// Sending half; clones share the channel.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half; clones share the channel.
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Error returned when all receivers are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Create a bounded channel with the given capacity (> 0).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0, "channel capacity must be > 0");
    let shared = Arc::new(Shared {
        queue: Mutex::new(State {
            items: VecDeque::with_capacity(capacity),
            senders: 1,
            receivers: 1,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; applies backpressure when the queue is full.
    /// Fails only when every receiver has been dropped.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.lock();
        loop {
            if st.receivers == 0 {
                return Err(SendError(value));
            }
            if st.items.len() < self.shared.capacity {
                st.items.push_back(value);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self
                .shared
                .not_full
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking send; returns the value back when full/closed.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.lock();
        if st.receivers == 0 || st.items.len() >= self.shared.capacity {
            return Err(SendError(value));
        }
        st.items.push_back(value);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Number of queued items (racy; diagnostics only).
    pub fn len(&self) -> usize {
        self.shared.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.lock().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.senders -= 1;
        if st.senders == 0 {
            // wake receivers so they can observe disconnection
            drop(st);
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once the channel is empty and all
    /// senders are gone.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.lock();
        loop {
            if let Some(v) = st.items.pop_front() {
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if st.senders == 0 {
                return None;
            }
            st = self
                .shared
                .not_empty
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.shared.lock();
        let v = st.items.pop_front();
        if v.is_some() {
            self.shared.not_full.notify_one();
        }
        v
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.lock().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.receivers -= 1;
        if st.receivers == 0 {
            drop(st);
            self.shared.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let got: Vec<i32> = (0..5).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn recv_none_after_senders_drop() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_receivers_drop() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(9), Err(SendError(9)));
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            // this send must block until the main thread receives
            tx.send(1).unwrap();
        });
        thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        t.join().unwrap();
    }

    #[test]
    fn try_send_respects_capacity() {
        let (tx, _rx) = bounded(1);
        assert!(tx.try_send(1).is_ok());
        assert_eq!(tx.try_send(2), Err(SendError(2)));
    }

    #[test]
    fn send_fails_after_all_receiver_clones_drop() {
        // the receiver count, not the original handle, gates send
        let (tx, rx) = bounded::<u32>(2);
        let rx2 = rx.clone();
        drop(rx);
        assert!(tx.send(1).is_ok(), "a live clone must keep sends alive");
        drop(rx2);
        assert_eq!(tx.send(2), Err(SendError(2)));
        assert_eq!(tx.try_send(3), Err(SendError(3)));
        // the error hands the undelivered value back to the caller
        assert_eq!(tx.send(4).unwrap_err().0, 4);
    }

    #[test]
    fn recv_drains_all_queued_items_after_senders_drop() {
        let (tx, rx) = bounded(8);
        let tx2 = tx.clone();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        tx2.send(3).unwrap();
        drop(tx);
        drop(tx2);
        // disconnection must not eat buffered items
        for i in 0..4 {
            assert_eq!(rx.recv(), Some(i));
        }
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.recv(), None, "None must be sticky");
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn blocked_send_errors_when_receiver_drops_mid_wait() {
        // a sender parked on a full queue must wake and fail, not hang,
        // when the last receiver disappears
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || tx.send(1));
        thread::sleep(Duration::from_millis(30)); // let the send park
        drop(rx);
        assert_eq!(t.join().unwrap(), Err(SendError(1)));
    }

    #[test]
    fn backpressure_blocks_exactly_at_capacity() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let (tx, rx) = bounded(2);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let t = thread::spawn(move || {
            for i in 0..5u32 {
                tx.send(i).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        thread::sleep(Duration::from_millis(40));
        // with nothing received, only `capacity` sends may complete
        assert_eq!(sent.load(Ordering::SeqCst), 2);
        let got: Vec<u32> = (0..5).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        t.join().unwrap();
    }

    #[test]
    fn try_recv_none_on_empty_but_connected_channel() {
        let (tx, rx) = bounded::<u32>(1);
        assert_eq!(rx.try_recv(), None);
        tx.send(9).unwrap();
        assert_eq!(rx.try_recv(), Some(9));
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(4);
        let mut senders = Vec::new();
        for s in 0..4u64 {
            let tx = tx.clone();
            senders.push(thread::spawn(move || {
                for i in 0..100u64 {
                    tx.send(s * 1000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut receivers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            receivers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for s in senders {
            s.join().unwrap();
        }
        let mut all: Vec<u64> = receivers
            .into_iter()
            .flat_map(|r| r.join().unwrap())
            .collect();
        all.sort();
        assert_eq!(all.len(), 400);
        all.dedup();
        assert_eq!(all.len(), 400, "duplicate delivery");
    }
}
