//! Bench: multi-stream scheduling cost and aggregate throughput as the
//! stream count grows 1 → 8 on one shared virtual accelerator.
//!
//! Two numbers matter here: the host-side cost of scheduling N streams
//! (the timed cases) and the *virtual* aggregate throughput the
//! schedule achieves (printed after each case — the accelerator-bound
//! figure an operator packs streams against).

use tod::bench::{black_box, Bench};
use tod::coordinator::multistream::{
    DispatchPolicy, MultiStreamResult, MultiStreamScheduler,
};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::OracleBackend;
use tod::coordinator::session::StreamSession;
use tod::dataset::catalog::{generate, SequenceId};
use tod::dataset::synth::Sequence;
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::sim::oracle::OracleDetector;

fn run_once(
    seqs: &[(SequenceId, Sequence)],
    n: usize,
    dispatch: DispatchPolicy,
) -> MultiStreamResult {
    let mut sched = MultiStreamScheduler::new(
        dispatch,
        ContentionModel::jetson_nano(),
        LatencyModel::deterministic(),
    );
    for i in 0..n {
        let (id, seq) = &seqs[i % seqs.len()];
        let det = OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ));
        sched.add_stream(
            StreamSession::new(seq, MbbsPolicy::tod_default(), id.eval_fps()),
            Box::new(det),
        );
    }
    sched.run()
}

fn main() {
    let mut b = Bench::slow();
    let seqs: Vec<(SequenceId, Sequence)> = SequenceId::ALL
        .iter()
        .map(|&id| (id, generate(id)))
        .collect();

    for n in [1usize, 2, 4, 8] {
        b.case(&format!("multistream/rr_{n}stream"), || {
            black_box(run_once(&seqs, n, DispatchPolicy::RoundRobin));
        });
        let r = run_once(&seqs, n, DispatchPolicy::RoundRobin);
        println!(
            "    -> virtual aggregate: {:.1} inf/s, util {:.1}%, \
             mean AP {:.3}, drop {:.1}%",
            r.utilisation.throughput_ips(),
            r.utilisation.utilisation() * 100.0,
            r.mean_ap(),
            r.drop_rate() * 100.0
        );
    }

    b.case("multistream/edf_8stream", || {
        black_box(run_once(&seqs, 8, DispatchPolicy::EarliestDeadlineFirst));
    });

    b.save_csv("multistream.csv").ok();
}
