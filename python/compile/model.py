"""L2: YOLOv4-style detector graphs at the paper's four operating points.

The paper serves four TensorRT engines: YOLOv4-tiny-288, YOLOv4-tiny-416,
YOLOv4-288 and YOLOv4-416. We reproduce the *serving architecture* — four
preloaded engines with distinct capacity/latency operating points — with
compact Darknet-style detectors whose convs all route through the L1
Pallas kernel (``compile.conv.conv2d_fused``).

Weights are deterministic (seeded He-init): there is no COCO training in
this reproduction (see DESIGN.md §3 — detection *quality* is modelled by
the Rust-side oracle calibrated to the paper's Fig. 4, while these graphs
carry the real compute on the request path).

Each variant lowers to one HLO module: image (1, S, S, 3) → tuple of raw
head tensors (1, GH, GW, A*(5+C)); box decoding happens in Rust
(``rust/src/runtime/decode.rs``) from the manifest this module emits.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .conv import conv2d_fused
from .kernels import maxpool2x2

NUM_CLASSES = 1  # 'person' — the paper filters detections to that label
ANCHORS_PER_SCALE = 3
HEAD_CHANNELS = ANCHORS_PER_SCALE * (5 + NUM_CLASSES)


@dataclasses.dataclass(frozen=True)
class VariantConfig:
    """One detector operating point (name matches the paper's)."""

    name: str
    input_size: int          # square input resolution (288 or 416)
    tiny: bool               # tiny topology (pool downsampling, 1 head)
    widths: tuple            # channel plan per stage
    head_strides: tuple      # output strides, one per detection head
    anchors: tuple           # per head: ((w, h) pixels at input scale, ...)
    seed: int = 0

    def grid_size(self, stride: int) -> int:
        assert self.input_size % stride == 0
        return self.input_size // stride


def _tiny_cfg(size: int) -> VariantConfig:
    return VariantConfig(
        name=f"yolov4-tiny-{size}",
        input_size=size,
        tiny=True,
        widths=(16, 32, 32, 64, 128),
        head_strides=(32,),
        anchors=(((23, 56), (52, 128), (110, 245)),),
        seed=1011,
    )


def _full_cfg(size: int) -> VariantConfig:
    return VariantConfig(
        name=f"yolov4-{size}",
        input_size=size,
        tiny=False,
        widths=(16, 32, 64, 96, 128),
        head_strides=(32, 16),
        anchors=(
            ((52, 128), (78, 180), (110, 245)),
            ((13, 30), (23, 56), (36, 88)),
        ),
        seed=2022,
    )


VARIANTS: dict = {
    "yolov4-tiny-288": _tiny_cfg(288),
    "yolov4-tiny-416": _tiny_cfg(416),
    "yolov4-288": _full_cfg(288),
    "yolov4-416": _full_cfg(416),
}


def _he_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def build_params(cfg: VariantConfig) -> dict:
    """Deterministic parameter pytree for a variant (seeded He init)."""
    key = jax.random.PRNGKey(cfg.seed + cfg.input_size)
    params: dict = {}

    def conv_param(name, kh, kw, cin, cout):
        nonlocal key
        key, sub = jax.random.split(key)
        params[f"{name}.w"] = _he_init(sub, kh, kw, cin, cout)
        params[f"{name}.b"] = jnp.zeros((cout,), jnp.float32)

    w = cfg.widths
    conv_param("stem", 3, 3, 3, w[0])           # /2
    conv_param("down2", 3, 3, w[0], w[1])       # /4
    if cfg.tiny:
        # Stages downsample with the Pallas max-pool kernel.
        conv_param("s3", 3, 3, w[1], w[2])      # pool -> /8
        conv_param("s4", 3, 3, w[2], w[3])      # pool -> /16
        conv_param("s5", 3, 3, w[3], w[4])      # pool -> /32
        conv_param("neck", 3, 3, w[4], w[4])
        conv_param("head32", 1, 1, w[4], HEAD_CHANNELS)
    else:
        conv_param("s3", 3, 3, w[1], w[2])      # stride 2 -> /8
        conv_param("s3b", 3, 3, w[2], w[2])
        conv_param("s4", 3, 3, w[2], w[3])      # stride 2 -> /16
        conv_param("s4b", 3, 3, w[3], w[3])
        conv_param("s5", 3, 3, w[3], w[4])      # stride 2 -> /32
        conv_param("s5b", 3, 3, w[4], w[4])
        conv_param("neck32", 3, 3, w[4], w[4])
        conv_param("head32", 1, 1, w[4], HEAD_CHANNELS)
        conv_param("neck16", 3, 3, w[3], w[3])
        conv_param("head16", 1, 1, w[3], HEAD_CHANNELS)
    return params


def forward(params: dict, image: jax.Array, cfg: VariantConfig,
            use_pallas: bool = True):
    """Detector forward pass: image -> tuple of raw head tensors.

    All convs run through the L1 fused Pallas kernel; tiny variants also
    exercise the Pallas max-pool kernel.
    """

    def conv(name, x, stride=1, act="leaky_relu"):
        return conv2d_fused(
            x, params[f"{name}.w"], params[f"{name}.b"],
            stride=stride, activation=act, use_pallas=use_pallas,
        )

    x = conv("stem", image, stride=2)
    x = conv("down2", x, stride=2)
    if cfg.tiny:
        x = conv("s3", x)
        x = maxpool2x2(x) if use_pallas else _ref_pool(x)
        x = conv("s4", x)
        x = maxpool2x2(x) if use_pallas else _ref_pool(x)
        x = conv("s5", x)
        x = maxpool2x2(x) if use_pallas else _ref_pool(x)
        x = conv("neck", x)
        h32 = conv("head32", x, act="linear")
        return (h32,)
    x = conv("s3", x, stride=2)
    x = conv("s3b", x)
    x = conv("s4", x, stride=2)
    x16 = conv("s4b", x)
    x = conv("s5", x16, stride=2)
    x = conv("s5b", x)
    x = conv("neck32", x)
    h32 = conv("head32", x, act="linear")
    y16 = conv("neck16", x16)
    h16 = conv("head16", y16, act="linear")
    return (h32, h16)


def _ref_pool(x):
    from .kernels import ref as kref

    return kref.ref_maxpool2x2(x)


def detector_fn(cfg: VariantConfig, use_pallas: bool = True) -> Callable:
    """Close over deterministic params: image -> head tuple (jit-able)."""
    params = build_params(cfg)

    def fn(image):
        return forward(params, image, cfg, use_pallas=use_pallas)

    return fn


def input_spec(cfg: VariantConfig) -> jax.ShapeDtypeStruct:
    s = cfg.input_size
    return jax.ShapeDtypeStruct((1, s, s, 3), jnp.float32)


def param_count(cfg: VariantConfig) -> int:
    params = build_params(cfg)
    return sum(int(p.size) for p in params.values())
