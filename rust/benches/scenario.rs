//! Bench: scenario compilation and replay throughput.
//!
//! The conformance harness replays 8 scenarios × 7 configurations per
//! `tod scenario check`, so compile + replay cost is what bounds CI
//! latency. Compilation (world synthesis) must stay trivially cheap
//! next to replay, and replay must stay far below a wall-second per
//! virtual-second — the printed frame counts give the per-frame cost.

use tod::bench::{black_box, Bench};
use tod::scenario::{
    run_scenario, scenario_spec, HarnessConfig, ScenarioId,
};

fn main() {
    let mut b = Bench::slow();

    // compilation: spec -> concrete phased sequences (world synthesis)
    {
        let spec = scenario_spec(ScenarioId::CameraHandoff);
        b.case("scenario/compile_camera_handoff", || {
            black_box(spec.compile().expect("compile").len());
        });
    }

    // single-stream replay: the regime-shifting relay feed
    {
        let spec = scenario_spec(ScenarioId::CameraHandoff);
        let streams = spec.compile().expect("compile");
        let frames: u64 = streams.iter().map(|s| s.seq.n_frames()).sum();
        let cfg = HarnessConfig::tod();
        b.case("scenario/replay_camera_handoff_tod", || {
            black_box(
                run_scenario(&spec.name, &streams, &cfg)
                    .expect("replay")
                    .mean_ap(),
            );
        });
        println!("    -> camera-handoff replays {frames} frames per iter");
    }

    // multi-stream churn replay: 3 sessions, staggered joins, shared
    // accelerator — the heaviest dispatch loop in the matrix
    {
        let spec = scenario_spec(ScenarioId::StreamChurn);
        let streams = spec.compile().expect("compile");
        let frames: u64 = streams.iter().map(|s| s.seq.n_frames()).sum();
        let cfg = HarnessConfig::tod();
        b.case("scenario/replay_stream_churn_tod", || {
            black_box(
                run_scenario(&spec.name, &streams, &cfg)
                    .expect("replay")
                    .mean_ap(),
            );
        });
        println!("    -> stream-churn replays {frames} frames per iter");
    }

    // budgeted replay: the governor on the per-frame path
    {
        let spec = scenario_spec(ScenarioId::BudgetSqueeze);
        let streams = spec.compile().expect("compile");
        let cfg = HarnessConfig::tod().with_watts(spec.watts_budget);
        b.case("scenario/replay_budget_squeeze_governed", || {
            black_box(
                run_scenario(&spec.name, &streams, &cfg)
                    .expect("replay")
                    .mean_ap(),
            );
        });
    }

    b.save_csv("scenario.csv").ok();
}
