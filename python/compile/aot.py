"""AOT lowering: JAX detector variants -> HLO text artifacts + manifest.

Runs once at build time (``make artifacts``); Python never executes on the
request path. Each of the four paper operating points lowers to one
``artifacts/<name>.hlo.txt`` module (weights baked as constants) that the
Rust runtime loads via ``HloModuleProto::from_text_file`` and compiles on
the PJRT CPU client.

Interchange is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

``artifacts/manifest.json`` describes every artifact (input shape, head
grids/strides/anchors, confidence decode layout) for the Rust decoder.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked detector weights must survive the
    # text round-trip — the default printer elides them as `{...}`, which
    # the Rust-side parser would reject.
    return comp.as_hlo_text(print_large_constants=True)


def lower_variant(cfg: model.VariantConfig, use_pallas: bool = True) -> str:
    fn = model.detector_fn(cfg, use_pallas=use_pallas)
    lowered = jax.jit(fn).lower(model.input_spec(cfg))
    return to_hlo_text(lowered)


def variant_manifest(cfg: model.VariantConfig, artifact: str,
                     hlo_sha256: str, hlo_bytes: int) -> dict:
    return {
        "name": cfg.name,
        "artifact": artifact,
        "input_shape": [1, cfg.input_size, cfg.input_size, 3],
        "input_size": cfg.input_size,
        "tiny": cfg.tiny,
        "param_count": model.param_count(cfg),
        "num_classes": model.NUM_CLASSES,
        "anchors_per_scale": model.ANCHORS_PER_SCALE,
        "hlo_sha256": hlo_sha256,
        "hlo_bytes": hlo_bytes,
        "heads": [
            {
                "stride": stride,
                "grid": cfg.grid_size(stride),
                "channels": model.HEAD_CHANNELS,
                "anchors": [list(a) for a in cfg.anchors[i]],
            }
            for i, stride in enumerate(cfg.head_strides)
        ],
    }


def build_all(out_dir: str, variants=None, use_pallas: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    names = variants or list(model.VARIANTS)
    manifest = {
        "format": "hlo-text",
        "generator": "python/compile/aot.py",
        "jax_version": jax.__version__,
        "pallas": use_pallas,
        "variants": [],
    }
    for name in names:
        cfg = model.VARIANTS[name]
        t0 = time.time()
        text = lower_variant(cfg, use_pallas=use_pallas)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        sha = hashlib.sha256(text.encode()).hexdigest()
        manifest["variants"].append(
            variant_manifest(cfg, fname, sha, len(text))
        )
        print(
            f"[aot] {name}: {len(text) / 1e6:.2f} MB HLO text, "
            f"{model.param_count(cfg)} params, "
            f"lowered in {time.time() - t0:.1f}s",
            file=sys.stderr,
        )
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {mpath}", file=sys.stderr)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifest")
    ap.add_argument("--variant", action="append",
                    help="lower only the named variant(s)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="ablation: lower via the pure-lax conv path")
    args = ap.parse_args()
    build_all(args.out, variants=args.variant,
              use_pallas=not args.no_pallas)


if __name__ == "__main__":
    main()
