//! Minimal threaded executor: a fixed worker pool and bounded channels
//! with backpressure (the offline stand-in for tokio; DESIGN.md §3).
//!
//! The serving example uses this to decouple the frame producer from the
//! PJRT inference worker while preserving the paper's single-inference-
//! in-flight discipline.

pub mod channel;
pub mod pool;

pub use channel::{bounded, Receiver, SendError, Sender};
pub use pool::{SubmitError, ThreadPool};
