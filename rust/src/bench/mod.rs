//! Criterion-style micro-benchmark harness (offline stand-in; DESIGN.md
//! §3). `cargo bench` drives the `rust/benches/*.rs` targets, each of
//! which uses [`Bench`] for warmup, timed iterations and robust stats.

pub mod harness;

pub use harness::{black_box, Bench, BenchResult};
