//! The `predictor` experiment: feature-driven projected-accuracy
//! selection vs the paper's threshold ladder and the fixed baselines.
//!
//! This is the beyond-the-paper study backing the second headline claim
//! (size *and speed* driven selection): per sequence, compare the
//! calibrated [`crate::coordinator::projected::ProjectedAccuracyPolicy`]
//! against TOD with `H_opt` and the best fixed single DNN, plus the
//! selection-behaviour summary (deployment mix and switches).

use crate::app::Campaign;
use crate::dataset::catalog::SequenceId;
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;
use crate::DnnKind;

use super::ExperimentOutput;

pub fn predictor_compare(c: &mut Campaign) -> ExperimentOutput {
    let mut table = AsciiTable::new(
        "Predictor — projected-accuracy selection vs TOD(H_opt) vs fixed \
         DNNs (real-time AP at eval FPS)",
        vec![
            "sequence",
            "best fixed",
            "AP(fixed)",
            "AP(tod)",
            "AP(projected)",
            "proj tiny%",
        ],
    );
    let mut csv = CsvTable::new(vec![
        "sequence",
        "best_fixed_dnn",
        "ap_best_fixed",
        "ap_tod",
        "ap_projected",
        "projected_tiny_share",
    ]);
    let (mut mean_fixed, mut mean_tod, mut mean_proj) = (0.0, 0.0, 0.0);
    // the best *single* fixed DNN across the whole catalog (one network
    // deployed everywhere — the deployment the paper's Fig. 8 beats)
    let mut fixed_catalog_mean = [0.0f64; DnnKind::COUNT];
    let n = SequenceId::ALL.len() as f64;
    for id in SequenceId::ALL {
        for k in DnnKind::ALL {
            fixed_catalog_mean[k.index()] +=
                c.realtime_fixed(id, k).ap / n;
        }
    }
    for id in SequenceId::ALL {
        let (best_kind, best_ap) = c.best_fixed_realtime(id);
        let tod_ap = c.tod(id).ap;
        let proj = c.projected(id).clone();
        let freq = proj.deploy_freq();
        let tiny = (freq[0] + freq[1]) * 100.0;
        table.push(vec![
            id.name().to_string(),
            best_kind.short_label().to_string(),
            format!("{best_ap:.3}"),
            format!("{tod_ap:.3}"),
            format!("{:.3}", proj.ap),
            format!("{tiny:.1}"),
        ]);
        csv.push(vec![
            id.name().to_string(),
            best_kind.artifact_name().to_string(),
            format!("{best_ap:.4}"),
            format!("{tod_ap:.4}"),
            format!("{:.4}", proj.ap),
            format!("{:.4}", tiny / 100.0),
        ]);
        mean_fixed += best_ap / n;
        mean_tod += tod_ap / n;
        mean_proj += proj.ap / n;
    }
    let best_single = DnnKind::ALL
        .iter()
        .copied()
        .max_by(|a, b| {
            fixed_catalog_mean[a.index()]
                .partial_cmp(&fixed_catalog_mean[b.index()])
                .unwrap()
        })
        .unwrap();
    let text = format!(
        "{}\nmeans: per-seq best fixed {mean_fixed:.3} | TOD(H_opt) \
         {mean_tod:.3} | projected {mean_proj:.3}\nbest single fixed DNN \
         over the catalog: {} at {:.3} mean AP\n(projected selection uses \
         the size x speed calibration table; `tod calibrate` persists it, \
         `tod run --policy projected` loads it)\n",
        table.render(),
        best_single.short_label(),
        fixed_catalog_mean[best_single.index()],
    );
    ExperimentOutput {
        id: "predictor",
        title: "Predictor: projected-accuracy selection".into(),
        text,
        csv: vec![("predictor_compare.csv".into(), csv)],
    }
}
