"""L1 kernel vs pure-jnp oracle: fused matmul + bias + activation.

The hypothesis sweep is the core correctness signal — it drives the
kernel across arbitrary (M, K, N) shapes, including those that require
zero-padding to the tile grid, and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fused_matmul_bias_act,
    mxu_utilisation_estimate,
    vmem_footprint_bytes,
)
from compile.kernels import ref

ACTIVATIONS = ["linear", "relu", "leaky_relu"]


def _rand(shape, seed, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("activation", ACTIVATIONS)
@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),          # degenerate
        (8, 16, 8),         # sub-tile
        (128, 128, 128),    # exactly one tile
        (129, 130, 131),    # one past the tile boundary everywhere
        (256, 64, 384),     # multi-tile M and N
        (1000, 27, 16),     # first-conv-like (im2col K=3*3*3)
    ],
)
def test_matmul_matches_ref(m, k, n, activation):
    x = _rand((m, k), seed=m * 7 + k)
    w = _rand((k, n), seed=n * 13 + k)
    b = _rand((n,), seed=n)
    out = fused_matmul_bias_act(x, w, b, activation=activation)
    expect = ref.ref_matmul_bias_act(x, w, b, activation=activation)
    assert out.shape == (m, n)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (32, 128, 256),
                                      (128, 256, 128), (16, 128, 64)])
def test_block_shape_invariance(bm, bn, bk):
    """Result must not depend on tile configuration."""
    x = _rand((200, 96), seed=1)
    w = _rand((96, 72), seed=2)
    b = _rand((72,), seed=3)
    base = fused_matmul_bias_act(x, w, b)
    tiled = fused_matmul_bias_act(x, w, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(base, tiled, rtol=1e-5, atol=1e-5)


def test_zero_k_padding_is_inert():
    """Padded K region must contribute exactly zero (bias still applied)."""
    x = jnp.zeros((4, 5), jnp.float32)
    w = jnp.zeros((5, 3), jnp.float32)
    b = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
    out = fused_matmul_bias_act(x, w, b, activation="linear")
    np.testing.assert_allclose(out, np.tile([1.0, -2.0, 0.5], (4, 1)),
                               atol=1e-7)


def test_leaky_relu_negative_slope():
    x = jnp.asarray([[1.0]], jnp.float32)
    w = jnp.asarray([[-1.0]], jnp.float32)
    b = jnp.zeros((1,), jnp.float32)
    out = fused_matmul_bias_act(x, w, b, activation="leaky_relu")
    np.testing.assert_allclose(out, [[-0.1]], rtol=1e-6)


def test_bfloat16_close_to_ref():
    x = _rand((64, 48), seed=10, dtype=jnp.bfloat16)
    w = _rand((48, 32), seed=11, dtype=jnp.bfloat16)
    b = _rand((32,), seed=12, dtype=jnp.bfloat16)
    out = fused_matmul_bias_act(x, w, b)
    expect = ref.ref_matmul_bias_act(x, w, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_bad_shapes_raise():
    x = jnp.zeros((4, 5), jnp.float32)
    w = jnp.zeros((6, 3), jnp.float32)  # K mismatch
    b = jnp.zeros((3,), jnp.float32)
    with pytest.raises(ValueError):
        fused_matmul_bias_act(x, w, b)
    with pytest.raises(ValueError):
        fused_matmul_bias_act(x[0], w, b)  # rank


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 180),
    k=st.integers(1, 140),
    n=st.integers(1, 150),
    activation=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(m, k, n, activation, seed):
    x = _rand((m, k), seed=seed)
    w = _rand((k, n), seed=seed + 1)
    b = _rand((n,), seed=seed + 2)
    out = fused_matmul_bias_act(x, w, b, activation=activation)
    expect = ref.ref_matmul_bias_act(x, w, b, activation=activation)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
)
def test_hypothesis_dtype_sweep(dtype, m, k, n):
    x = _rand((m, k), seed=m, dtype=dtype)
    w = _rand((k, n), seed=n, dtype=dtype)
    b = _rand((n,), seed=k, dtype=dtype)
    out = fused_matmul_bias_act(x, w, b)
    expect = ref.ref_matmul_bias_act(x, w, b)
    tol = 1e-4 if dtype == jnp.float32 else 7e-2
    assert out.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol,
    )


def test_vmem_footprint_budget():
    """Default tile config must fit a Jetson-class VMEM-ish budget with
    double-buffering (16 MiB VMEM on TPU; we keep < 4 MiB headroom)."""
    bytes_ = vmem_footprint_bytes(128, 128, 128)
    assert bytes_ < 4 * 1024 * 1024
    assert bytes_ > 0


def test_mxu_utilisation_estimate_bounds():
    assert mxu_utilisation_estimate(128, 128, 128, 128, 128, 128) == 1.0
    u = mxu_utilisation_estimate(129, 1, 1, 128, 128, 128)
    assert 0.0 < u < 0.01
    # utilisation never exceeds 1
    for mnk in [(7, 9, 11), (300, 5, 77)]:
        assert mxu_utilisation_estimate(*mnk, 128, 128, 128) <= 1.0
