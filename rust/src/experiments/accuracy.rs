//! Figures 4, 6, 7, 8: the accuracy quartet.

use crate::app::Campaign;
use crate::dataset::catalog::SequenceId;
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;
use crate::DnnKind;

use super::ExperimentOutput;

fn dnn_header() -> Vec<String> {
    std::iter::once("sequence".to_string())
        .chain(DnnKind::ALL.iter().map(|k| k.artifact_name().to_string()))
        .collect()
}

/// Fig. 4: offline-mode AP per DNN per sequence.
pub fn fig4_offline(c: &mut Campaign) -> ExperimentOutput {
    let header = dnn_header();
    let mut table = AsciiTable::new(
        "Fig. 4 — Average Precision (Offline Mode)",
        header.iter().map(String::as_str).collect(),
    );
    let mut csv = CsvTable::new(header);
    for id in SequenceId::ALL {
        let mut row = vec![id.name().to_string()];
        for k in DnnKind::ALL {
            row.push(format!("{:.3}", c.offline(id, k).ap));
        }
        table.push(row.clone());
        csv.push(row);
    }
    ExperimentOutput {
        id: "fig4",
        title: "Fig. 4: offline AP".into(),
        text: table.render(),
        csv: vec![("fig4_offline_ap.csv".into(), csv)],
    }
}

/// Fig. 6: real-time-mode AP per DNN per sequence (30 FPS; -05 at 14).
pub fn fig6_realtime(c: &mut Campaign) -> ExperimentOutput {
    let header = dnn_header();
    let mut table = AsciiTable::new(
        "Fig. 6 — Average Precision (Real-Time Mode)",
        header.iter().map(String::as_str).collect(),
    );
    let mut csv = CsvTable::new(header);
    for id in SequenceId::ALL {
        let mut row = vec![format!("{} @{}fps", id.name(), id.eval_fps())];
        for k in DnnKind::ALL {
            row.push(format!("{:.3}", c.realtime_fixed(id, k).ap));
        }
        table.push(row.clone());
        csv.push(row);
    }
    ExperimentOutput {
        id: "fig6",
        title: "Fig. 6: real-time AP".into(),
        text: table.render(),
        csv: vec![("fig6_realtime_ap.csv".into(), csv)],
    }
}

/// Fig. 7: AP drop from offline to real-time.
pub fn fig7_drop(c: &mut Campaign) -> ExperimentOutput {
    let header = dnn_header();
    let mut table = AsciiTable::new(
        "Fig. 7 — AP Drop from Offline to Real-Time",
        header.iter().map(String::as_str).collect(),
    );
    let mut csv = CsvTable::new(header);
    for id in SequenceId::ALL {
        let mut row = vec![id.name().to_string()];
        for k in DnnKind::ALL {
            let drop = c.offline(id, k).ap - c.realtime_fixed(id, k).ap;
            row.push(format!("{:.3}", drop));
        }
        table.push(row.clone());
        csv.push(row);
    }
    ExperimentOutput {
        id: "fig7",
        title: "Fig. 7: offline→real-time AP drop".into(),
        text: table.render(),
        csv: vec![("fig7_ap_drop.csv".into(), csv)],
    }
}

/// Fig. 8: TOD vs the four fixed DNNs (real-time), plus the headline
/// mean improvements and the chameleon-lite baseline.
pub fn fig8_tod(c: &mut Campaign) -> ExperimentOutput {
    let mut header = dnn_header();
    header.push("TOD".into());
    header.push("chameleon-lite".into());
    let mut table = AsciiTable::new(
        "Fig. 8 — Average Precision Comparison (Real-Time)",
        header.iter().map(String::as_str).collect(),
    );
    let mut csv = CsvTable::new(header);
    for id in SequenceId::ALL {
        let mut row = vec![id.name().to_string()];
        for k in DnnKind::ALL {
            row.push(format!("{:.3}", c.realtime_fixed(id, k).ap));
        }
        row.push(format!("{:.3}", c.tod(id).ap));
        row.push(format!("{:.3}", c.chameleon(id).ap));
        table.push(row.clone());
        csv.push(row);
    }
    let imp = c.improvement_over_fixed();
    let text = format!(
        "{}\nTOD mean-AP improvement vs fixed DNNs: \
         {:+.1}% (tiny-288), {:+.1}% (tiny-416), {:+.1}% (288), {:+.1}% (416)\n\
         (paper: +34.7%, +7.0%, +3.9%, +2.0%)\n",
        table.render(),
        imp[0],
        imp[1],
        imp[2],
        imp[3]
    );
    ExperimentOutput {
        id: "fig8",
        title: "Fig. 8: TOD vs fixed DNNs".into(),
        text,
        csv: vec![("fig8_comparison.csv".into(), csv)],
    }
}
