//! Power-subsystem integration tests: golden no-budget equivalence,
//! incremental-vs-post-hoc metering, the budgeted-TOD resource-saving
//! acceptance run (ISSUE 3), shared-board budgets across streams, and
//! the DVFS rate-cap trade.

use tod::app::{Campaign, DEFAULT_WATTS_BUDGET};
use tod::coordinator::multistream::{DispatchPolicy, MultiStreamScheduler};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::run_realtime;
use tod::coordinator::session::{SessionEvent, StreamSession};
use tod::dataset::catalog::SequenceId;
use tod::dataset::synth::Sequence;
use tod::power::{
    BudgetedPolicy, EnergyMeter, PowerBudget, RateCap, SharedBudget,
};
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::testing::fixtures::{oracle_for, small_object_stream, SeqBuilder};
use tod::DnnKind;

/// Small-object synthetic stream: TOD leans on the heavy networks, so
/// a watts budget actually binds.
fn small_object_seq(seed: u64, frames: u64) -> Sequence {
    small_object_stream("PWR", seed, frames)
}

/// Golden equivalence: a [`BudgetedPolicy`] with no caps must be
/// bit-identical to its inner policy over the full synth catalog —
/// same per-frame selections, schedule, drops and AP.
#[test]
fn no_budget_wrapper_is_bit_identical_on_full_catalog() {
    let mut c = Campaign::new();
    for id in SequenceId::ALL {
        let bare = c.tod(id).clone();
        let seq = c.sequence(id).clone();
        let mut wrapped = BudgetedPolicy::masking(
            Box::new(MbbsPolicy::tod_default()),
            PowerBudget::unbounded(),
        );
        let mut lat = LatencyModel::deterministic();
        let r = run_realtime(
            &seq,
            &mut wrapped,
            &mut oracle_for(&seq),
            &mut lat,
            id.eval_fps(),
        );
        assert_eq!(
            r.dnn_series,
            bare.dnn_series,
            "{}: per-frame selections diverged",
            id.name()
        );
        assert_eq!(r.deploy_counts, bare.deploy_counts, "{}", id.name());
        assert_eq!(r.n_dropped, bare.n_dropped, "{}", id.name());
        assert_eq!(r.ap, bare.ap, "{}", id.name());
        assert_eq!(r.trace.busy, bare.trace.busy, "{}", id.name());
        assert_eq!(r.power, bare.power, "{}", id.name());
    }
}

/// The session's per-step meter must equal post-hoc metering of its
/// finished trace — online accounting is the telemetry, not an
/// approximation of it.
#[test]
fn incremental_metering_matches_post_hoc() {
    let mut c = Campaign::new();
    let seq = c.sequence(SequenceId::Mot09).clone();
    let mut det = oracle_for(&seq);
    let mut lat = LatencyModel::deterministic();
    let mut s =
        StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0);
    let mut steps = 0u64;
    loop {
        if s.step(&mut det, &mut lat) == SessionEvent::Finished {
            break;
        }
        steps += 1;
        if steps % 100 == 0 {
            // mid-run: the busy/inference accounting already agrees
            let post = EnergyMeter::from_trace(s.trace()).summary();
            let online = s.power();
            assert_eq!(online.busy_per_dnn_s, post.busy_per_dnn_s);
            assert_eq!(online.inferences, post.inferences);
        }
    }
    let r = s.finish();
    assert_eq!(r.power, EnergyMeter::from_trace(&r.trace).summary());
    // sanity: the run did meter something
    assert!(r.power.energy_j > 0.0);
    assert!(r.power.gpu_busy_frac > 0.0);
}

/// The acceptance run (ISSUE 3): under a watts budget below the
/// heaviest DNN's active power, budgeted TOD's catalog-mean AP must be
/// at least the best budget-feasible fixed DNN's, while its metered
/// average power and GPU-busy fraction stay strictly below an
/// unbudgeted always-YOLOv4-416 deployment — the paper's §IV.D shape
/// (45.1% GPU, 62.7% power on MOT17-05, no accuracy loss).
#[test]
fn budgeted_tod_saves_resources() {
    let cap = DEFAULT_WATTS_BUDGET;
    assert!(
        cap < 7.5,
        "the budget must sit below Y-416's active power (Fig. 14)"
    );
    let mut c = Campaign::new();
    let n = SequenceId::ALL.len() as f64;

    // fixed baselines: metered power decides budget feasibility
    let mut fixed_mean_ap = [0.0f64; DnnKind::COUNT];
    let mut fixed_feasible = [true; DnnKind::COUNT];
    for k in DnnKind::ALL {
        for id in SequenceId::ALL {
            let r = c.realtime_fixed(id, k);
            fixed_mean_ap[k.index()] += r.ap / n;
            if r.power.avg_power_w > cap {
                fixed_feasible[k.index()] = false;
            }
        }
    }
    // the cap separates the variants exactly as designed: tiny
    // deployments fit, saturated full-YOLO deployments do not
    assert!(fixed_feasible[DnnKind::TinyY288.index()]);
    assert!(fixed_feasible[DnnKind::TinyY416.index()]);
    assert!(!fixed_feasible[DnnKind::Y416.index()]);
    let best_feasible_ap = DnnKind::ALL
        .iter()
        .filter(|k| fixed_feasible[k.index()])
        .map(|k| fixed_mean_ap[k.index()])
        .fold(f64::NEG_INFINITY, f64::max);

    let mut budgeted_mean_ap = 0.0;
    let mut mean_busy_budgeted = 0.0;
    let mut mean_busy_y416 = 0.0;
    for id in SequenceId::ALL {
        let y416 = c.realtime_fixed(id, DnnKind::Y416).power;
        let b = c.power_budgeted(id, cap).clone();
        budgeted_mean_ap += b.ap / n;
        mean_busy_budgeted += b.power.gpu_busy_frac / n;
        mean_busy_y416 += y416.gpu_busy_frac / n;
        // the governor actually enforces the cap (small slack for
        // window-boundary effects)
        assert!(
            b.power.avg_power_w <= cap + 0.25,
            "{}: budgeted avg power {} exceeds cap {cap}",
            id.name(),
            b.power.avg_power_w
        );
        // strictly below the unbudgeted always-Y-416 run, everywhere
        assert!(
            b.power.avg_power_w < y416.avg_power_w,
            "{}: power {} vs Y-416 {}",
            id.name(),
            b.power.avg_power_w,
            y416.avg_power_w
        );
        // never busier than the saturated Y-416 deployment
        assert!(
            b.power.gpu_busy_frac <= y416.gpu_busy_frac + 1e-9,
            "{}: GPU busy {} vs Y-416 {}",
            id.name(),
            b.power.gpu_busy_frac,
            y416.gpu_busy_frac
        );
    }
    // ... and strictly less busy in aggregate (tiny selections leave
    // real idle gaps the always-saturated Y-416 run never has)
    assert!(
        mean_busy_budgeted < mean_busy_y416,
        "mean GPU busy {mean_busy_budgeted} vs Y-416 {mean_busy_y416}"
    );
    assert!(
        budgeted_mean_ap >= best_feasible_ap,
        "budgeted TOD mean AP {budgeted_mean_ap:.4} must not lose to \
         the best budget-feasible fixed DNN {best_feasible_ap:.4} \
         ({fixed_mean_ap:?}, feasible {fixed_feasible:?})"
    );

    // the headline sequence: budgeted TOD on MOT17-05 reproduces the
    // paper's resource ratios against always-Y-416
    let y416 = c.realtime_fixed(SequenceId::Mot05, DnnKind::Y416).power;
    let b05 = c.power_budgeted(SequenceId::Mot05, cap).power;
    let gpu_ratio = b05.gpu_busy_frac / y416.gpu_busy_frac;
    let pow_ratio = b05.avg_power_w / y416.avg_power_w;
    assert!(gpu_ratio < 0.65, "GPU ratio {gpu_ratio} (paper: 0.451)");
    assert!(pow_ratio < 0.80, "power ratio {pow_ratio} (paper: 0.627)");
}

/// One shared governor across two streams on one accelerator: the
/// board-level power obeys the cap, and sits below the same deployment
/// without a budget.
#[test]
fn shared_board_budget_governs_all_streams() {
    let cap = 5.0;
    let run = |shared: Option<SharedBudget>| {
        let seqs: Vec<Sequence> =
            (0..2).map(|i| small_object_seq(40 + i, 240)).collect();
        let mut sched = MultiStreamScheduler::new(
            DispatchPolicy::RoundRobin,
            ContentionModel::none(),
            LatencyModel::deterministic(),
        );
        for seq in &seqs {
            let policy: Box<dyn tod::coordinator::policy::SelectionPolicy> =
                match &shared {
                    Some(b) => Box::new(BudgetedPolicy::masking_shared(
                        Box::new(MbbsPolicy::tod_default()),
                        b.clone(),
                    )),
                    None => Box::new(MbbsPolicy::tod_default()),
                };
            sched.add_stream(
                StreamSession::new(seq, policy, 30.0),
                Box::new(oracle_for(seq)),
            );
        }
        sched.run()
    };

    let unbudgeted = run(None);
    let shared =
        PowerBudget::watts(cap, &LatencyModel::deterministic()).shared();
    let budgeted = run(Some(shared.clone()));

    // small objects drive TOD to the heavy nets; unbudgeted the board
    // runs hot, over the cap
    assert!(
        unbudgeted.power.avg_power_w > cap,
        "unbudgeted board power {} should exceed the {cap} W cap",
        unbudgeted.power.avg_power_w
    );
    assert!(
        budgeted.power.avg_power_w <= cap + 0.3,
        "shared budget failed to hold the board at {cap} W: {}",
        budgeted.power.avg_power_w
    );
    assert!(
        budgeted.power.avg_power_w < unbudgeted.power.avg_power_w,
        "budgeted {} vs unbudgeted {}",
        budgeted.power.avg_power_w,
        unbudgeted.power.avg_power_w
    );
    // both streams' inferences flowed through the one governor
    assert!(shared.borrow().now() > 0.0);
}

/// DVFS rate cap: stretching latencies at `scale²` dynamic power cuts
/// board power on the same stream, at the cost of more dropped frames.
#[test]
fn rate_cap_trades_drops_for_power() {
    // large close-up objects: TOD stays on tiny-288, which meets 30
    // FPS at nominal clocks (no drops, 81% duty) but not at 0.7x —
    // so the rate cap visibly trades drops/busy-time for watts
    let seq = SeqBuilder::new("PWR-RATE", 7)
        .frames(300)
        .ref_height(500.0)
        .depth_range(1.0, 1.6)
        .build();
    let fps = 30.0;
    let mut lat = LatencyModel::deterministic();
    let mut pol = MbbsPolicy::tod_default();
    let nominal =
        run_realtime(&seq, &mut pol, &mut oracle_for(&seq), &mut lat, fps);

    let rc = RateCap::new(0.7);
    let mut lat_capped = rc.stretch(&LatencyModel::deterministic());
    let mut pol = MbbsPolicy::tod_default();
    let capped = run_realtime(
        &seq,
        &mut pol,
        &mut oracle_for(&seq),
        &mut lat_capped,
        fps,
    );
    let mut meter = EnergyMeter::with_active_scale(rc.power_factor());
    meter.fold_trace(&capped.trace);

    assert!(
        capped.n_dropped >= nominal.n_dropped,
        "stretched latencies cannot drop fewer frames: {} vs {}",
        capped.n_dropped,
        nominal.n_dropped
    );
    assert!(
        meter.avg_power_w() < nominal.power.avg_power_w,
        "rate-capped power {} must undercut nominal {}",
        meter.avg_power_w(),
        nominal.power.avg_power_w
    );
    // busy fraction goes the other way: the slower clock works longer
    assert!(meter.gpu_busy_frac() > nominal.power.gpu_busy_frac);
}
