//! Predictor-subsystem integration tests: golden equivalence with
//! Algorithm 1, the calibrate → persist → load → select round-trip, and
//! the headline acceptance run on the mixed-motion synth catalog.

use tod::app::Campaign;
use tod::coordinator::policy::{MbbsPolicy, Thresholds};
use tod::coordinator::projected::ProjectedAccuracyPolicy;
use tod::coordinator::scheduler::run_realtime;
use tod::dataset::catalog::{generate, SequenceId};
use tod::features::FrameFeatures;
use tod::predictor::store;
use tod::predictor::{calibrate, CalibrationConfig, CalibrationTable};
use tod::sim::latency::LatencyModel;
use tod::testing::fixtures::oracle_for;
use tod::DnnKind;

/// Golden equivalence: `ProjectedAccuracyPolicy` degenerated to
/// size-only selection (one speed bin, ladder-shaped AP surface) must
/// reproduce `MbbsPolicy` frame for frame on the full synth catalog —
/// same per-frame DNN choices, same schedule, same AP. This pins the
/// trait widening: the feature path cannot perturb Algorithm 1.
#[test]
fn golden_ladder_equivalence_on_full_catalog() {
    let th = Thresholds::h_opt();
    for id in SequenceId::ALL {
        let seq = generate(id);
        let mut mbbs_pol = MbbsPolicy::new(th.clone());
        let mut proj = ProjectedAccuracyPolicy::new(
            CalibrationTable::from_ladder(&th, &DnnKind::ALL),
            &LatencyModel::deterministic(),
        );
        let mut lat_a = LatencyModel::deterministic();
        let mut lat_b = LatencyModel::deterministic();
        let a = run_realtime(
            &seq,
            &mut mbbs_pol,
            &mut oracle_for(&seq),
            &mut lat_a,
            id.eval_fps(),
        );
        let b = run_realtime(
            &seq,
            &mut proj,
            &mut oracle_for(&seq),
            &mut lat_b,
            id.eval_fps(),
        );
        assert_eq!(
            a.dnn_series,
            b.dnn_series,
            "{}: per-frame selections diverged",
            id.name()
        );
        assert_eq!(a.deploy_counts, b.deploy_counts, "{}", id.name());
        assert_eq!(a.n_dropped, b.n_dropped, "{}", id.name());
        assert_eq!(a.ap, b.ap, "{}", id.name());
        assert_eq!(a.mbbs_series, b.mbbs_series, "{}", id.name());
    }
}

/// The CI smoke test: calibrate a small table, persist it, load it
/// back, and select through both copies identically.
#[test]
fn calibrate_roundtrip_smoke() {
    let table = calibrate(&CalibrationConfig::quick(30.0));
    let dir = std::env::temp_dir().join("tod_predictor_roundtrip");
    let path = dir.join("calibration.json");
    store::save(&table, &path).unwrap();
    let loaded = store::load(&path).unwrap();
    assert_eq!(loaded, table);

    let lat = LatencyModel::deterministic();
    let from_mem = ProjectedAccuracyPolicy::new(table, &lat);
    let from_disk = ProjectedAccuracyPolicy::new(loaded, &lat);
    for &size in &[0.0, 0.003, 0.01, 0.04, 0.2] {
        for &speed in &[0.0, 0.003, 0.01, 0.03] {
            let f = FrameFeatures {
                mbbs: size,
                count: 8,
                density: size * 8.0,
                speed,
            };
            assert_eq!(
                from_mem.select_pure(&f),
                from_disk.select_pure(&f),
                "diverged at size={size} speed={speed}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance run (ISSUE 2): on the mixed-motion synth catalog the
/// calibrated projected-accuracy policy must achieve mean AP at least
/// that of `MbbsPolicy` with `H_opt`, and strictly above the best fixed
/// single-DNN deployment.
#[test]
fn projected_mean_ap_beats_ladder_and_best_fixed() {
    let mut c = Campaign::new();
    let n = SequenceId::ALL.len() as f64;
    let mut mean_tod = 0.0;
    let mut mean_proj = 0.0;
    let mut fixed_mean = [0.0f64; 4];
    for id in SequenceId::ALL {
        mean_tod += c.tod(id).ap / n;
        mean_proj += c.projected(id).ap / n;
        for k in DnnKind::ALL {
            fixed_mean[k.index()] += c.realtime_fixed(id, k).ap / n;
        }
    }
    let best_fixed =
        fixed_mean.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    assert!(
        mean_proj >= mean_tod,
        "projected mean AP {mean_proj:.4} must not lose to TOD(H_opt) \
         {mean_tod:.4}"
    );
    assert!(
        mean_proj > best_fixed,
        "projected mean AP {mean_proj:.4} must beat the best single \
         fixed DNN {best_fixed:.4} ({fixed_mean:?})"
    );
}

/// The speed channel is the point of the subsystem: on a fast-moving
/// large-object stream the projected policy must deploy lighter
/// networks than the size-only ladder would on the same sizes.
#[test]
fn projected_responds_to_speed_not_just_size() {
    let mut c = Campaign::new();
    // MOT17-09: large boxes under a 30 px/frame pan — the regime where
    // carried heavy-DNN boxes go stale fastest
    let proj = c.projected(SequenceId::Mot09).clone();
    let freq = proj.deploy_freq();
    assert!(
        freq[DnnKind::TinyY288.index()] + freq[DnnKind::TinyY416.index()]
            > 0.5,
        "MOT17-09 under projected selection should be tiny-dominant: \
         {freq:?}"
    );
    // and the static far-field MOT17-04 must stay with the heavy nets
    let proj04 = c.projected(SequenceId::Mot04).clone();
    let freq04 = proj04.deploy_freq();
    assert!(
        freq04[DnnKind::Y288.index()] + freq04[DnnKind::Y416.index()] > 0.9,
        "MOT17-04 under projected selection should stay heavy: {freq04:?}"
    );
}
