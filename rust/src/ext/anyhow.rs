//! Minimal `anyhow`-compatible error handling for the PJRT runtime.
//!
//! Implements exactly the subset `runtime/{engine,pool,serve,manifest}.rs`
//! uses: an opaque [`Error`] carrying a context chain, [`Result`], the
//! [`anyhow!`]/[`bail!`] macros, and the [`Context`] extension trait for
//! `Result` and `Option`. Like the real crate, [`Error`] deliberately
//! does *not* implement `std::error::Error`, which is what makes the
//! blanket `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// Opaque error: a message plus the chain of contexts wrapped around it.
pub struct Error {
    /// Outermost context first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Root error from anything displayable (what `anyhow!` expands to).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap one more layer of context around this error.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.chain.is_empty() {
            return f.write_str("unknown error");
        }
        if f.alternate() {
            // `{:#}`: the whole chain on one line, like the real crate
            return f.write_str(&self.chain.join(": "));
        }
        f.write_str(&self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain.iter().enumerate() {
            if i == 0 {
                writeln!(f, "{msg}")?;
            } else {
                if i == 1 {
                    writeln!(f, "\nCaused by:")?;
                }
                writeln!(f, "    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::ext::anyhow::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::ext::anyhow::Error::msg(format!($($arg)*)))
    };
}

pub(crate) use anyhow;
pub(crate) use bail;

/// Attach context to errors (`Result`) or absence (`Option`).
pub trait Context<T> {
    /// Wrap the error with `context` (eagerly evaluated).
    fn context(self, context: impl fmt::Display) -> Result<T>;
    /// Wrap the error with lazily-built context.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, context: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, context: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let err = io_err()
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(err.to_string(), "reading manifest");
        assert_eq!(err.root_cause(), "gone");
        let chain: Vec<_> = err.chain().collect();
        assert_eq!(chain, ["reading manifest", "gone"]);
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context_and_macros() {
        let missing: Option<u32> = None;
        let err = missing.context("no value").unwrap_err();
        assert_eq!(err.to_string(), "no value");

        fn bails() -> Result<()> {
            bail!("code {}", 7);
        }
        assert_eq!(bails().unwrap_err().to_string(), "code 7");
        assert_eq!(anyhow!("x={}", 1).to_string(), "x=1");
    }

    #[test]
    fn alternate_display_joins_the_chain() {
        let err = io_err().context("reading manifest").unwrap_err();
        assert_eq!(format!("{err:#}"), "reading manifest: gone");
        assert_eq!(format!("{err}"), "reading manifest");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let err = io_err()
            .context("inner")
            .map_err(|e| e.context("outer"))
            .unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("gone"));
    }
}
