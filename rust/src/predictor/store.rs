//! Versioned JSON persistence for [`CalibrationTable`].
//!
//! The calibration campaign is minutes of compute; runtime selection is
//! microseconds. The table is therefore persisted once (`tod calibrate`)
//! and loaded at startup (`tod run --policy projected`). The schema is
//! deliberately explicit (a `schema` tag plus a `version` integer) so a
//! binary never silently misreads a table produced by a different
//! calibration generation — see DESIGN.md §9 for the full schema.
//!
//! ```json
//! {
//!   "schema": "tod-calibration-table",
//!   "version": 1,
//!   "fps": 30,
//!   "size_axis": [0.002, 0.005, ...],
//!   "speed_axis": [0.0, 0.002, ...],
//!   "projected_ap": {
//!     "yolov4-tiny-288": [[...speed cells...], ...one row per size...],
//!     "yolov4-tiny-416": [[...]], "yolov4-288": [[...]], "yolov4-416": [[...]]
//!   }
//! }
//! ```

use std::path::Path;

use crate::util::json::Json;
use crate::DnnKind;

use super::model::{CalibrationTable, TABLE_VERSION};

/// The `schema` tag identifying a calibration-table document.
pub const SCHEMA_TAG: &str = "tod-calibration-table";

/// Serialize a table to the versioned JSON document.
pub fn to_json(table: &CalibrationTable) -> Json {
    let axis = |a: &[f64]| Json::arr(a.iter().map(|&v| Json::num(v)));
    let mut dnns = Vec::new();
    for k in DnnKind::ALL {
        let grid = &table.ap[k.index()];
        let rows = grid
            .iter()
            .map(|row| Json::arr(row.iter().map(|&v| Json::num(v))));
        dnns.push((k.artifact_name(), Json::arr(rows)));
    }
    Json::obj(vec![
        ("schema", Json::str(SCHEMA_TAG)),
        ("version", Json::num(TABLE_VERSION as f64)),
        ("fps", Json::num(table.fps)),
        ("size_axis", axis(&table.size_axis)),
        ("speed_axis", axis(&table.speed_axis)),
        ("projected_ap", Json::obj(dnns)),
    ])
}

/// Parse and validate a table from its JSON document.
pub fn from_json(doc: &Json) -> Result<CalibrationTable, String> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("missing 'schema' tag")?;
    if schema != SCHEMA_TAG {
        return Err(format!(
            "wrong schema: {schema:?} (want {SCHEMA_TAG:?})"
        ));
    }
    let version = doc
        .get("version")
        .and_then(Json::as_usize)
        .ok_or("missing 'version'")?;
    if version != TABLE_VERSION as usize {
        return Err(format!(
            "calibration table version {version} unsupported (this build \
             reads version {TABLE_VERSION}; re-run `tod calibrate`)"
        ));
    }
    let fps = doc
        .get("fps")
        .and_then(Json::as_f64)
        .ok_or("missing 'fps'")?;
    let axis = |key: &str| -> Result<Vec<f64>, String> {
        doc.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("missing '{key}'"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| format!("non-numeric value in {key}"))
            })
            .collect()
    };
    let size_axis = axis("size_axis")?;
    let speed_axis = axis("speed_axis")?;
    let grids = doc
        .get("projected_ap")
        .ok_or("missing 'projected_ap'")?;
    let mut ap = Vec::with_capacity(DnnKind::ALL.len());
    for k in DnnKind::ALL {
        let name = k.artifact_name();
        let grid = grids
            .get(name)
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("missing grid for {name}"))?;
        let mut rows = Vec::with_capacity(grid.len());
        for row in grid {
            let cells = row
                .as_arr()
                .ok_or_else(|| format!("{name}: grid row is not an array"))?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| format!("{name}: non-numeric AP cell"))
                })
                .collect::<Result<Vec<f64>, String>>()?;
            rows.push(cells);
        }
        ap.push(rows);
    }
    let table = CalibrationTable { fps, size_axis, speed_axis, ap };
    table.validate()?;
    Ok(table)
}

/// Write a table to `path` as pretty JSON (parent dirs created).
pub fn save(table: &CalibrationTable, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, to_json(table).to_pretty())
}

/// Load and validate a table from `path`.
pub fn load(path: &Path) -> Result<CalibrationTable, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    from_json(&doc).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_table() -> CalibrationTable {
        let ap = (0..4)
            .map(|d| {
                (0..3)
                    .map(|s| {
                        (0..2)
                            .map(|v| {
                                (0.1 * (d + 1) as f64
                                    + 0.01 * s as f64
                                    + 0.001 * v as f64)
                                    .min(1.0)
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        CalibrationTable::new(
            30.0,
            vec![0.002, 0.01, 0.05],
            vec![0.001, 0.01],
            ap,
        )
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let t = sample_table();
        let doc = to_json(&t);
        let back = from_json(&doc).unwrap();
        assert_eq!(back, t);
        // and through actual text serialization
        let reparsed = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(from_json(&reparsed).unwrap(), t);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample_table();
        let dir = std::env::temp_dir().join("tod_calib_store_test");
        let path = dir.join("calibration.json");
        save(&t, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, t);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_schema_and_version_rejected() {
        let t = sample_table();
        let doc = to_json(&t);
        let mut wrong_schema = doc.clone();
        if let Json::Obj(m) = &mut wrong_schema {
            m.insert("schema".into(), Json::str("not-a-table"));
        }
        assert!(from_json(&wrong_schema).unwrap_err().contains("schema"));
        let mut wrong_version = doc;
        if let Json::Obj(m) = &mut wrong_version {
            m.insert("version".into(), Json::num(99.0));
        }
        assert!(from_json(&wrong_version)
            .unwrap_err()
            .contains("version 99"));
    }

    #[test]
    fn structural_errors_reported() {
        let t = sample_table();
        let mut doc = to_json(&t);
        if let Json::Obj(m) = &mut doc {
            m.remove("projected_ap");
        }
        assert!(from_json(&doc).unwrap_err().contains("projected_ap"));
        assert!(load(Path::new("/nonexistent/calibration.json")).is_err());
    }
}
