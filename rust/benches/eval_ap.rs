//! Bench: the evaluation substrate (IoU matching + AP integration),
//! sized like one MOT sequence.

use tod::bench::{black_box, Bench};
use tod::dataset::catalog::{generate, SequenceId};
use tod::eval::ap::{ApMethod, SequenceEval};
use tod::eval::matching::{match_frame, IOU_THRESHOLD};
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn main() {
    let mut b = Bench::new();
    let seq = generate(SequenceId::Mot04); // densest sequence (42 peds)
    let oracle = OracleDetector::new(seq.spec.seed, 1920.0, 1080.0);
    let gt = seq.gt(100);
    let dets = oracle.detect(100, gt, DnnKind::Y416);

    b.case("match_frame/dense_42gt", || {
        black_box(match_frame(black_box(&dets), black_box(gt), IOU_THRESHOLD));
    });

    // a whole-sequence AP evaluation (matching pre-computed)
    let matches: Vec<_> = (1..=seq.n_frames())
        .map(|f| {
            let d = oracle.detect(f, seq.gt(f), DnnKind::Y416);
            match_frame(&d, seq.gt(f), IOU_THRESHOLD)
        })
        .collect();
    b.case("ap/sequence_1050_frames", || {
        let mut e = SequenceEval::new();
        for m in &matches {
            e.push(m);
        }
        black_box(e.ap(ApMethod::AllPoint));
    });

    b.case("oracle/detect_dense_frame", || {
        black_box(oracle.detect(
            black_box(100),
            black_box(gt),
            DnnKind::Y416,
        ));
    });

    b.save_csv("eval_ap.csv").ok();
}
