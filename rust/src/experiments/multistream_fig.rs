//! Multi-stream scaling study: aggregate accuracy and drop rate as
//! stream count grows on one shared accelerator (beyond the paper —
//! the ROMA-style many-cameras-one-GPU regime).

use crate::app::{Campaign, MULTISTREAM_SCALE};
use crate::coordinator::multistream::DispatchPolicy;
use crate::util::csv::CsvTable;

use super::ExperimentOutput;

/// `tod figures --id multistream`: the 1→8 stream sweep under both
/// dispatch orders.
pub fn multistream_scaling(campaign: &mut Campaign) -> ExperimentOutput {
    let mut csv = CsvTable::new(vec![
        "dispatch",
        "n_streams",
        "mean_ap",
        "drop_rate",
        "utilisation",
        "throughput_ips",
    ]);
    let mut text = String::from(
        "Multi-stream scaling (TOD policy per stream, shared accelerator,\n\
         Jetson contention model):\n\
         dispatch      streams  mean AP  drop%   util%   inf/s\n",
    );
    for dispatch in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::EarliestDeadlineFirst,
    ] {
        for row in campaign.multistream_scaling(dispatch) {
            text.push_str(&format!(
                "{:<13} {:>7}  {:>7.3}  {:>5.1}  {:>6.1}  {:>6.1}\n",
                dispatch.label(),
                row.n_streams,
                row.mean_ap,
                row.drop_rate * 100.0,
                row.utilisation * 100.0,
                row.throughput_ips,
            ));
            csv.push(vec![
                dispatch.label().to_string(),
                row.n_streams.to_string(),
                format!("{:.4}", row.mean_ap),
                format!("{:.4}", row.drop_rate),
                format!("{:.4}", row.utilisation),
                format!("{:.2}", row.throughput_ips),
            ]);
        }
    }
    ExperimentOutput {
        id: "multistream",
        title: format!(
            "Multi-stream scaling over {:?} streams",
            MULTISTREAM_SCALE
        ),
        text,
        csv: vec![("multistream_scaling.csv".to_string(), csv)],
    }
}
