//! One module per paper artifact: the harness behind `tod figures`.
//!
//! Every table and figure in the paper's evaluation section has a
//! generator here that prints the same rows/series the paper reports and
//! writes a machine-readable CSV next to it. See DESIGN.md §5 for the
//! experiment index and EXPERIMENTS.md for paper-vs-measured results.

pub mod ablation;
pub mod accuracy;
pub mod latency_fig;
pub mod multistream_fig;
pub mod policy_stats;
pub mod power_fig;
pub mod predictor_fig;
pub mod scenario_fig;
pub mod table1;
pub mod telemetry_figs;

use std::path::Path;

use crate::app::Campaign;
use crate::util::csv::CsvTable;

/// Output of one experiment generator.
pub struct ExperimentOutput {
    pub id: &'static str,
    pub title: String,
    /// Human-readable rendering (tables / sparklines).
    pub text: String,
    /// Machine-readable series, written to `<out>/<name>.csv`.
    pub csv: Vec<(String, CsvTable)>,
}

impl ExperimentOutput {
    /// Write all CSVs under `out_dir`.
    pub fn save(&self, out_dir: &Path) -> std::io::Result<()> {
        for (name, table) in &self.csv {
            table.save(&out_dir.join(name))?;
        }
        Ok(())
    }
}

/// All experiment ids: the paper's artifacts in paper order, then the
/// beyond-the-paper studies.
pub const ALL_IDS: [&str; 18] = [
    "table1", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
    "fig11", "fig12", "fig13", "fig14", "fig15", "ablations",
    "multistream", "predictor", "power", "scenario",
];

/// Run one experiment by id.
pub fn run(id: &str, campaign: &mut Campaign) -> Option<ExperimentOutput> {
    match id {
        "table1" => Some(table1::run()),
        "fig4" => Some(accuracy::fig4_offline(campaign)),
        "fig5" => Some(latency_fig::fig5_latency()),
        "fig6" => Some(accuracy::fig6_realtime(campaign)),
        "fig7" => Some(accuracy::fig7_drop(campaign)),
        "fig8" => Some(accuracy::fig8_tod(campaign)),
        "fig9" => Some(policy_stats::fig9_mbbs(campaign)),
        "fig10" => Some(policy_stats::fig10_deploy(campaign)),
        "fig11" => Some(telemetry_figs::fig11_memory()),
        "fig12" => Some(policy_stats::fig12_usage(campaign)),
        "fig13" => Some(telemetry_figs::fig13_gpu(campaign)),
        "fig14" => Some(telemetry_figs::fig14_power_single(campaign)),
        "fig15" => Some(telemetry_figs::fig15_power_tod(campaign)),
        "ablations" => Some(ablation::run_all()),
        "multistream" => {
            Some(multistream_fig::multistream_scaling(campaign))
        }
        "predictor" => Some(predictor_fig::predictor_compare(campaign)),
        "power" => Some(power_fig::power_table(campaign)),
        "scenario" => Some(scenario_fig::scenario_table(campaign)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_id() {
        let mut c = Campaign::new();
        // cheap ids run fully; expensive ids are covered by the
        // integration suite and the figures CLI
        for id in ["fig5", "fig11"] {
            let out = run(id, &mut c).expect(id);
            assert_eq!(out.id, id);
            assert!(!out.text.is_empty());
            assert!(!out.csv.is_empty());
        }
        assert!(run("fig99", &mut c).is_none());
        assert!(ALL_IDS.contains(&"table1"));
        assert!(ALL_IDS.contains(&"ablations"));
    }

    #[test]
    fn output_save_writes_csvs() {
        let mut c = Campaign::new();
        let out = run("fig11", &mut c).unwrap();
        let dir = std::env::temp_dir().join("tod_exp_save");
        out.save(&dir).unwrap();
        let written = std::fs::read_to_string(
            dir.join("fig11_memory.csv"),
        )
        .unwrap();
        assert!(written.starts_with("configuration,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
