//! Scenario-matrix conformance suite (ISSUE 5 acceptance):
//!
//! * the full 8-scenario matrix replays bit-identically against the
//!   goldens under `tests/goldens/` (bootstrapping them on a fresh
//!   checkout — commit the files to pin them; see the README there);
//! * the differential layer holds on every scenario: projected (and
//!   watts-budgeted) adaptive selection never loses to the best
//!   (budget-feasible) fixed DNN, with the margins recorded per
//!   scenario in the golden;
//! * every recorded run document round-trips losslessly through the
//!   versioned `tod-scenario-run` schema;
//! * the harness is a conservative extension: a single-stream, single-
//!   phase, clean scenario reproduces `run_realtime` bit for bit.

use std::path::PathBuf;

use tod::scenario::conformance::{
    self, golden_path, CheckVerdict, MATRIX_FPS,
};
use tod::scenario::matrix::ScenarioId;
use tod::scenario::{record, scenario_spec};
use tod::util::json::Json;

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens")
}

/// The acceptance run: record-or-verify the whole matrix, then read
/// the (now byte-verified) goldens back for the differential margins
/// and the schema round-trip. One test so the matrix replays once.
#[test]
fn matrix_conformance_differential_and_schema() {
    let dir = goldens_dir();
    let bootstrapped =
        conformance::bootstrap_goldens_if_missing(&dir).expect("record");
    if bootstrapped {
        eprintln!(
            "note: no goldens were committed under {} — recorded them; \
             the following check independently re-runs the matrix and \
             verifies byte-identical replay",
            dir.display()
        );
    }

    // byte-exact conformance: re-runs every scenario x config from its
    // seed and compares against the files on disk
    let results = conformance::check_goldens(&dir).expect("check");
    assert_eq!(results.len(), ScenarioId::ALL.len());
    for (name, verdict) in &results {
        match verdict {
            CheckVerdict::Match => {}
            CheckVerdict::Missing => {
                panic!("{name}: golden missing (run `tod scenario record`)")
            }
            CheckVerdict::Mismatch { line, golden, observed } => panic!(
                "{name}: replay diverged from the golden at line {line}\n  \
                 golden:   {golden}\n  observed: {observed}"
            ),
        }
    }

    // the goldens now provably equal current behaviour: read the
    // differential margins and the run documents back from disk
    for id in ScenarioId::ALL {
        let path = golden_path(&dir, id.name());
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let doc = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(conformance::SCHEMA_TAG),
            "{id}"
        );

        let d = doc.get("differential").expect("differential section");
        let margin = |key: &str| {
            d.get(key)
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("{id}: missing {key}"))
        };
        // ISSUE 5 acceptance: adaptive selection must not lose to the
        // best fixed DNN on ANY scenario of the matrix (budgeted runs
        // compare against the best budget-feasible fixed DNN)
        assert!(
            margin("projected_margin") >= -1e-9,
            "{id}: projected lost to {} by {}",
            d.get("best_fixed").and_then(Json::as_str).unwrap_or("?"),
            margin("projected_margin")
        );
        assert!(
            margin("budgeted_margin") >= -1e-9,
            "{id}: budgeted lost to {} by {}",
            d.get("best_feasible_fixed")
                .and_then(Json::as_str)
                .unwrap_or("?"),
            margin("budgeted_margin")
        );

        // every embedded run document round-trips losslessly through
        // the versioned schema (golden-stability satellite)
        let runs = doc.get("runs").and_then(Json::as_arr).expect("runs");
        assert_eq!(runs.len(), 3 + tod::DnnKind::COUNT, "{id}");
        for run in runs {
            let parsed = record::from_json(run)
                .unwrap_or_else(|e| panic!("{id}: bad run record: {e}"));
            assert_eq!(
                record::to_json(&parsed),
                *run,
                "{id}: run record round-trip lost information"
            );
            assert_eq!(parsed.scenario, id.name());
            // conservation inside the canonical record
            let a = &parsed.aggregate;
            assert_eq!(a.inferred + a.dropped, a.frames, "{id}");
        }
    }
}

/// The pinned-goldens gate: every golden committed under
/// `tests/goldens/` must replay byte-identically with **no**
/// `--bootstrap` escape hatch — this test never records, it only
/// verifies. On a checkout still in the bootstrap state (README only,
/// no `.json`) it reports and passes, because there is nothing pinned
/// to defend yet; the CI golden-pin guard is what keeps that state
/// from persisting silently.
#[test]
fn committed_goldens_replay_without_bootstrap() {
    let dir = goldens_dir();
    let committed = std::fs::read_dir(&dir)
        .map(|rd| {
            rd.filter_map(Result::ok)
                .filter(|e| {
                    e.path().extension().is_some_and(|x| x == "json")
                })
                .count()
        })
        .unwrap_or(0);
    if committed == 0 {
        eprintln!(
            "note: {} holds no goldens — bootstrap state, nothing \
             pinned to verify (the conformance test above records and \
             cross-checks; commit its output to activate this gate)",
            dir.display()
        );
        return;
    }
    assert_eq!(
        committed,
        ScenarioId::ALL.len(),
        "{}: partial golden set — re-run `tod scenario record` and \
         commit all {} scenarios",
        dir.display(),
        ScenarioId::ALL.len()
    );
    for (name, verdict) in conformance::check_goldens(&dir).expect("check") {
        assert!(
            matches!(verdict, CheckVerdict::Match),
            "{name}: committed golden failed strict replay: {verdict:?}"
        );
    }
}

/// Determinism without any files: replaying one scenario twice from
/// its seed yields byte-identical canonical records.
#[test]
fn same_seed_reproduces_the_record_byte_for_byte() {
    use tod::scenario::{run_scenario, HarnessConfig, RunRecord};
    let spec = scenario_spec(ScenarioId::CameraHandoff);
    assert_eq!(spec.base_fps, MATRIX_FPS);
    let streams = spec.compile().expect("compile");
    let text_of = || {
        let run = run_scenario(&spec.name, &streams, &HarnessConfig::tod())
            .expect("run");
        RunRecord::from_run(&run, spec.seed).canonical_text()
    };
    assert_eq!(text_of(), text_of());
}
