//! MOT file-format integration: a generated sequence written to disk,
//! read back, and evaluated must behave identically to the in-memory
//! path (so real MOT17Det downloads drop into the same pipeline).

use tod::dataset::catalog::{generate, SequenceId};
use tod::dataset::mot;
use tod::detection::Detection;
use tod::eval::ap::{ApMethod, SequenceEval};
use tod::eval::matching::{match_frame, IOU_THRESHOLD};
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

#[test]
fn gt_file_roundtrip_preserves_evaluation() {
    let seq = generate(SequenceId::Mot09);
    let dir = std::env::temp_dir().join("tod_mot_roundtrip");
    let gt_path = dir.join("gt.txt");
    mot::write_file(&gt_path, &seq.all_entries()).unwrap();
    let loaded = mot::read_file(&gt_path).unwrap();
    let frames = mot::group_by_frame(&loaded, seq.n_frames());

    let oracle = OracleDetector::new(seq.spec.seed, 1920.0, 1080.0);
    let mut eval_mem = SequenceEval::new();
    let mut eval_disk = SequenceEval::new();
    for f in 1..=seq.n_frames() {
        let dets: Vec<Detection> = oracle
            .detect(f, seq.gt(f), DnnKind::Y416)
            .into_iter()
            .filter(|d| d.score > 0.35)
            .collect();
        eval_mem.push(&match_frame(&dets, seq.gt(f), IOU_THRESHOLD));
        eval_disk.push(&match_frame(
            &dets,
            &frames[(f - 1) as usize],
            IOU_THRESHOLD,
        ));
    }
    let (a, b) = (eval_mem.ap(ApMethod::AllPoint), eval_disk.ap(ApMethod::AllPoint));
    assert!(
        (a - b).abs() < 5e-3,
        "in-memory {a} vs disk-roundtrip {b}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn det_file_export_in_paper_format() {
    // the paper writes detections as: frame, -1, x, y, w, h, score,
    // classID, -1 (visibility meaningless for detections)
    let seq = generate(SequenceId::Mot05);
    let oracle = OracleDetector::new(seq.spec.seed, 640.0, 480.0);
    let mut rows = Vec::new();
    for f in 1..=10 {
        let dets = oracle.detect(f, seq.gt(f), DnnKind::TinyY288);
        rows.extend(mot::detections_to_entries(f, &dets));
    }
    let dir = std::env::temp_dir().join("tod_det_export");
    let path = dir.join("det.txt");
    mot::write_file(&path, &rows).unwrap();
    let back = mot::read_file(&path).unwrap();
    assert_eq!(back.len(), rows.len());
    for e in &back {
        assert_eq!(e.id, -1);
        assert_eq!(e.visibility, -1.0);
        assert!(e.conf > 0.0 && e.conf < 1.0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn preprocessing_mirrors_paper_flag_rules() {
    // synthetic sequences emit pedestrians + static persons only; verify
    // the preprocessing used on real MOT17Det leaves them intact and
    // drops a synthetic car row
    let seq = generate(SequenceId::Mot02);
    let mut entries = seq.all_entries();
    let n_before = entries.iter().filter(|e| e.is_considered()).count();
    entries.push(mot::GtEntry::parse("1,999,5,5,50,50,1,3,1").unwrap());
    let processed: Vec<_> = entries
        .into_iter()
        .map(|e| e.preprocess_for_eval())
        .collect();
    let n_after = processed.iter().filter(|e| e.is_considered()).count();
    assert_eq!(n_before, n_after);
}
