//! Micro-batch collection for the inference server: per-DNN pending
//! queues with size- and deadline-bounded flushing.
//!
//! Requests from concurrent streams accumulate per variant; a queue
//! becomes *due* the moment it holds [`BatchConfig::max_batch`] items
//! (size flush) or its oldest request has waited
//! [`BatchConfig::max_wait`] (deadline flush — batching must never add
//! unbounded latency to a lone stream). [`MicroBatcher`] is the pure
//! data structure; the locking, completion handles and execution live
//! in [`super::server`], and the deterministic virtual-time counterpart
//! used by the simulator is
//! [`crate::sim::latency::BatchLatencyModel`].

// This module is on the serving path: no unwrap/expect — every failure
// mode must surface as a value, not a panic.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::DnnKind;

/// What to do with a request that arrives while the pending queue is
/// at [`BatchConfig::queue_cap`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Apply backpressure: the submitting stream blocks until space
    /// frees up (the default — no request is ever silently lost).
    Block,
    /// Shed load: reject immediately with a queue-full error the
    /// caller can downgrade on (e.g. carry the previous detections).
    Shed,
}

/// Tunables for the micro-batching server.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Flush a variant's queue as soon as it holds this many requests.
    pub max_batch: usize,
    /// Flush a variant's queue once its oldest request has waited this
    /// long, even if the batch is not full.
    pub max_wait: Duration,
    /// Bound on requests admitted but not yet dispatched (admission
    /// control across all variants).
    pub queue_cap: usize,
    /// Policy when the queue is at capacity.
    pub admission: AdmissionPolicy,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 64,
            admission: AdmissionPolicy::Block,
        }
    }
}

impl BatchConfig {
    /// Validate the configuration, naming the offending field.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("max_batch must be >= 1".into());
        }
        if self.queue_cap == 0 {
            return Err("queue_cap must be >= 1".into());
        }
        if self.queue_cap < self.max_batch {
            return Err(format!(
                "queue_cap ({}) must be >= max_batch ({}) or full \
                 batches could never form",
                self.queue_cap, self.max_batch
            ));
        }
        Ok(())
    }
}

/// Per-variant batch accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct VariantBatchStats {
    /// Batches dispatched.
    pub batches: u64,
    /// Requests carried by those batches.
    pub items: u64,
    /// Largest batch dispatched.
    pub largest: usize,
}

impl VariantBatchStats {
    /// Mean items per batch (0.0 before the first dispatch).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.items as f64 / self.batches as f64
        }
    }
}

/// Batch statistics across all variants, plus admission shedding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchStats {
    /// Indexed by [`DnnKind::index`].
    pub per_dnn: [VariantBatchStats; DnnKind::COUNT],
    /// Requests rejected by [`AdmissionPolicy::Shed`].
    pub shed: u64,
}

impl Default for BatchStats {
    fn default() -> Self {
        BatchStats {
            per_dnn: [VariantBatchStats::default(); DnnKind::COUNT],
            shed: 0,
        }
    }
}

impl BatchStats {
    /// Fold one dispatched batch into the accounting.
    pub fn record(&mut self, dnn: DnnKind, n: usize) {
        let v = &mut self.per_dnn[dnn.index()];
        v.batches += 1;
        v.items += n as u64;
        v.largest = v.largest.max(n);
    }

    pub fn total_batches(&self) -> u64 {
        self.per_dnn.iter().map(|v| v.batches).sum()
    }

    pub fn total_items(&self) -> u64 {
        self.per_dnn.iter().map(|v| v.items).sum()
    }

    /// Mean items per batch over every variant.
    pub fn mean_batch(&self) -> f64 {
        let b = self.total_batches();
        if b == 0 {
            0.0
        } else {
            self.total_items() as f64 / b as f64
        }
    }
}

impl std::fmt::Display for BatchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} batches / {} items (mean {:.2}/batch",
            self.total_batches(),
            self.total_items(),
            self.mean_batch()
        )?;
        if self.shed > 0 {
            write!(f, ", {} shed", self.shed)?;
        }
        write!(f, ")")?;
        for k in DnnKind::ALL {
            let v = &self.per_dnn[k.index()];
            if v.batches > 0 {
                write!(
                    f,
                    "\n  {:16} {:>5} batches, mean {:.2}, largest {}",
                    k.artifact_name(),
                    v.batches,
                    v.mean_batch(),
                    v.largest
                )?;
            }
        }
        Ok(())
    }
}

/// Queue index -> variant. Indices are always `< DnnKind::COUNT` by
/// construction; fall back to the heaviest variant rather than
/// panicking on the serving path.
fn variant_at(idx: usize) -> DnnKind {
    DnnKind::from_index(idx).unwrap_or(DnnKind::Y416)
}

/// One pending request with its enqueue time.
struct Pending<T> {
    since: Instant,
    item: T,
}

/// Per-DNN pending queues with size/deadline flush rules. Pure data
/// structure: the caller supplies `now` explicitly, which keeps every
/// flush decision deterministic and unit-testable.
pub struct MicroBatcher<T> {
    max_batch: usize,
    max_wait: Duration,
    queues: Vec<VecDeque<Pending<T>>>,
    queued: usize,
    high_water: usize,
}

impl<T> MicroBatcher<T> {
    /// `max_batch >= 1`; a zero `max_wait` makes every request due
    /// immediately (degenerates to per-request dispatch when paired
    /// with `max_batch == 1`). Each per-variant queue pre-reserves
    /// `max_batch` slots; use
    /// [`with_queue_capacity`](Self::with_queue_capacity) to reserve
    /// more up front.
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self::with_queue_capacity(max_batch, max_wait, max_batch)
    }

    /// Like [`new`](Self::new) but pre-reserves `reserve` slots in
    /// every per-variant queue, so a dispatch loop that never exceeds
    /// that occupancy performs no queue reallocation in steady state
    /// (pair with [`pop_due_into`](Self::pop_due_into) /
    /// [`pop_any_into`](Self::pop_any_into) for a fully alloc-free hot
    /// path). The server passes its admission bound
    /// [`BatchConfig::queue_cap`] here.
    pub fn with_queue_capacity(
        max_batch: usize,
        max_wait: Duration,
        reserve: usize,
    ) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        MicroBatcher {
            max_batch,
            max_wait,
            queues: (0..DnnKind::COUNT)
                .map(|_| VecDeque::with_capacity(reserve.max(max_batch)))
                .collect(),
            queued: 0,
            high_water: 0,
        }
    }

    /// Total pending requests across every variant.
    pub fn len(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Peak simultaneous occupancy since construction (all variants).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Enqueue one request for `dnn` at time `now`.
    pub fn push(&mut self, dnn: DnnKind, item: T, now: Instant) {
        self.queues[dnn.index()].push_back(Pending { since: now, item });
        self.queued += 1;
        self.high_water = self.high_water.max(self.queued);
    }

    /// Earliest deadline-flush instant over the non-empty queues, or
    /// `None` when nothing is pending. A full queue is due *now*.
    pub fn next_deadline(&self) -> Option<Instant> {
        let mut earliest: Option<Instant> = None;
        for q in &self.queues {
            let Some(head) = q.front() else { continue };
            let due = if q.len() >= self.max_batch {
                head.since // already due: deadline in the past
            } else {
                head.since + self.max_wait
            };
            earliest = Some(match earliest {
                Some(e) if e <= due => e,
                _ => due,
            });
        }
        earliest
    }

    /// Queue index and batch size of the most urgent due batch at time
    /// `now`: full queues first (largest wins), then expired queues by
    /// oldest head; ties break on the lower variant index.
    fn due_index(&self, now: Instant) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, Instant)> = None;
        for (i, q) in self.queues.iter().enumerate() {
            let Some(head) = q.front() else { continue };
            let full = q.len() >= self.max_batch;
            let expired = now.duration_since(head.since) >= self.max_wait;
            if !full && !expired {
                continue;
            }
            let candidate = (i, q.len().min(self.max_batch), head.since);
            best = Some(match best {
                // prefer larger batches, then older heads
                Some(b) if b.1 > candidate.1
                    || (b.1 == candidate.1 && b.2 <= candidate.2) =>
                {
                    b
                }
                _ => candidate,
            });
        }
        best.map(|(idx, take, _)| (idx, take))
    }

    /// Pop the most urgent due batch at time `now` (see
    /// [`due_index`](Self::due_index) for the ordering). Returns up to
    /// `max_batch` items in a fresh `Vec`; the dispatch loop should
    /// prefer [`pop_due_into`](Self::pop_due_into), which reuses one.
    pub fn pop_due(&mut self, now: Instant) -> Option<(DnnKind, Vec<T>)> {
        let (idx, take) = self.due_index(now)?;
        Some((variant_at(idx), self.drain(idx, take)))
    }

    /// Allocation-free [`pop_due`](Self::pop_due): drains the due batch
    /// into the caller-owned `out` (cleared first) and returns its
    /// variant. With `out.capacity() >= max_batch` and queues sized via
    /// [`with_queue_capacity`](Self::with_queue_capacity), the steady
    /// dispatch loop touches the allocator zero times.
    pub fn pop_due_into(
        &mut self,
        now: Instant,
        out: &mut Vec<T>,
    ) -> Option<DnnKind> {
        let (idx, take) = self.due_index(now)?;
        self.drain_into(idx, take, out);
        Some(variant_at(idx))
    }

    /// Pop any pending batch regardless of deadlines (shutdown drain).
    pub fn pop_any(&mut self) -> Option<(DnnKind, Vec<T>)> {
        let idx = self.queues.iter().position(|q| !q.is_empty())?;
        let take = self.queues[idx].len().min(self.max_batch);
        Some((variant_at(idx), self.drain(idx, take)))
    }

    /// Allocation-free [`pop_any`](Self::pop_any) (shutdown drain into
    /// a reused buffer).
    pub fn pop_any_into(&mut self, out: &mut Vec<T>) -> Option<DnnKind> {
        let idx = self.queues.iter().position(|q| !q.is_empty())?;
        let take = self.queues[idx].len().min(self.max_batch);
        self.drain_into(idx, take, out);
        Some(variant_at(idx))
    }

    fn drain(&mut self, idx: usize, n: usize) -> Vec<T> {
        let mut out = Vec::with_capacity(n);
        self.drain_into(idx, n, &mut out);
        out
    }

    fn drain_into(&mut self, idx: usize, n: usize, out: &mut Vec<T>) {
        out.clear();
        let q = &mut self.queues[idx];
        out.extend(q.drain(..n).map(|p| p.item));
        self.queued -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn config_validation_names_the_field() {
        assert!(BatchConfig::default().validate().is_ok());
        let bad = BatchConfig { max_batch: 0, ..BatchConfig::default() };
        assert!(bad.validate().unwrap_err().contains("max_batch"));
        let bad = BatchConfig { queue_cap: 0, ..BatchConfig::default() };
        assert!(bad.validate().unwrap_err().contains("queue_cap"));
        let bad = BatchConfig {
            max_batch: 8,
            queue_cap: 4,
            ..BatchConfig::default()
        };
        assert!(bad.validate().unwrap_err().contains("full"));
    }

    #[test]
    fn size_flush_at_max_batch() {
        let mut b = MicroBatcher::new(3, Duration::from_secs(3600));
        let now = t0();
        b.push(DnnKind::Y416, 1u32, now);
        b.push(DnnKind::Y416, 2, now);
        assert!(b.pop_due(now).is_none(), "not full, not expired");
        b.push(DnnKind::Y416, 3, now);
        let (dnn, items) = b.pop_due(now).expect("full queue is due");
        assert_eq!(dnn, DnnKind::Y416);
        assert_eq!(items, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_flush_after_max_wait() {
        let wait = Duration::from_millis(50);
        let mut b = MicroBatcher::new(8, wait);
        let now = t0();
        b.push(DnnKind::TinyY288, 7u32, now);
        assert!(b.pop_due(now).is_none());
        assert_eq!(b.next_deadline(), Some(now + wait));
        let (dnn, items) =
            b.pop_due(now + wait).expect("expired queue is due");
        assert_eq!(dnn, DnnKind::TinyY288);
        assert_eq!(items, vec![7]);
    }

    #[test]
    fn oversize_queue_flushes_in_max_batch_chunks() {
        let mut b = MicroBatcher::new(2, Duration::from_secs(3600));
        let now = t0();
        for i in 0..5u32 {
            b.push(DnnKind::Y288, i, now);
        }
        assert_eq!(b.len(), 5);
        assert_eq!(b.pop_due(now), Some((DnnKind::Y288, vec![0, 1])));
        assert_eq!(b.pop_due(now), Some((DnnKind::Y288, vec![2, 3])));
        // the remainder is below max_batch and not yet expired
        assert_eq!(b.pop_due(now), None);
        assert_eq!(b.pop_any(), Some((DnnKind::Y288, vec![4])));
        assert!(b.is_empty());
    }

    #[test]
    fn fuller_queue_wins_then_older_head() {
        let mut b = MicroBatcher::new(4, Duration::from_millis(10));
        let now = t0();
        b.push(DnnKind::TinyY288, 1u32, now);
        b.push(DnnKind::Y416, 2, now);
        b.push(DnnKind::Y416, 3, now);
        let later = now + Duration::from_millis(20);
        // both expired; Y-416 holds more items so it flushes first
        assert_eq!(b.pop_due(later), Some((DnnKind::Y416, vec![2, 3])));
        assert_eq!(b.pop_due(later), Some((DnnKind::TinyY288, vec![1])));
    }

    #[test]
    fn variants_never_mix_in_one_batch() {
        let mut b = MicroBatcher::new(2, Duration::ZERO);
        let now = t0();
        b.push(DnnKind::TinyY288, 1u32, now);
        b.push(DnnKind::Y416, 2, now);
        let mut seen = Vec::new();
        while let Some((dnn, items)) = b.pop_due(now) {
            assert_eq!(items.len(), 1);
            seen.push(dnn);
        }
        assert_eq!(seen.len(), 2);
        assert_ne!(seen[0], seen[1]);
    }

    #[test]
    fn stats_accumulate_and_render() {
        let mut s = BatchStats::default();
        s.record(DnnKind::Y416, 4);
        s.record(DnnKind::Y416, 2);
        s.record(DnnKind::TinyY288, 1);
        assert_eq!(s.total_batches(), 3);
        assert_eq!(s.total_items(), 7);
        assert!((s.mean_batch() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.per_dnn[DnnKind::Y416.index()].largest, 4);
        assert!((s.per_dnn[DnnKind::Y416.index()].mean_batch() - 3.0).abs()
            < 1e-12);
        let text = s.to_string();
        assert!(text.contains("3 batches"));
        assert!(text.contains("yolov4-416"));
    }

    #[test]
    fn empty_batcher_has_no_deadline() {
        let b: MicroBatcher<u32> =
            MicroBatcher::new(4, Duration::from_millis(1));
        assert_eq!(b.next_deadline(), None);
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let now = t0();
        let mk = || {
            let mut b = MicroBatcher::new(2, Duration::ZERO);
            b.push(DnnKind::Y416, 1u32, now);
            b.push(DnnKind::Y416, 2, now);
            b.push(DnnKind::TinyY288, 3, now);
            b
        };
        let mut a = mk();
        let mut b = mk();
        let mut out = Vec::new();
        while let Some((dnn, items)) = a.pop_due(now) {
            assert_eq!(b.pop_due_into(now, &mut out), Some(dnn));
            assert_eq!(out, items);
        }
        assert_eq!(b.pop_due_into(now, &mut out), None);
        let mut a = mk();
        let mut b = mk();
        while let Some((dnn, items)) = a.pop_any() {
            assert_eq!(b.pop_any_into(&mut out), Some(dnn));
            assert_eq!(out, items);
        }
        assert_eq!(b.pop_any_into(&mut out), None);
        assert!(b.is_empty());
    }

    #[test]
    fn high_water_tracks_peak_occupancy() {
        let mut b = MicroBatcher::new(2, Duration::ZERO);
        let now = t0();
        assert_eq!(b.high_water(), 0);
        b.push(DnnKind::Y416, 1u32, now);
        b.push(DnnKind::Y288, 2, now);
        b.push(DnnKind::Y288, 3, now);
        assert_eq!(b.high_water(), 3);
        while b.pop_any().is_some() {}
        // draining never lowers the recorded peak
        assert!(b.is_empty());
        assert_eq!(b.high_water(), 3);
        b.push(DnnKind::Y416, 4, now);
        assert_eq!(b.high_water(), 3);
    }

    #[test]
    fn steady_state_dispatch_is_alloc_free() {
        let now = t0();
        let mut b = MicroBatcher::with_queue_capacity(
            4,
            Duration::from_millis(2),
            16,
        );
        let mut out: Vec<u32> = Vec::with_capacity(4);
        // warm-up: touch every queue and the out buffer once
        for k in DnnKind::ALL {
            b.push(k, 0u32, now);
        }
        while b.pop_any_into(&mut out).is_some() {}
        let (delta, flushed) = crate::perf::count_allocs(|| {
            let mut flushed = 0usize;
            for round in 0..8u32 {
                for i in 0..4u32 {
                    b.push(DnnKind::Y288, round * 4 + i, now);
                }
                while b.pop_due_into(now, &mut out).is_some() {
                    flushed += out.len();
                }
            }
            flushed
        });
        assert_eq!(flushed, 32, "every pushed request must flush");
        assert_eq!(
            delta.allocs, 0,
            "steady-state push/pop_due_into must not allocate \
             ({} allocs, {} bytes)",
            delta.allocs, delta.bytes
        );
    }
}
