//! The power/utilisation budget governor: watts and GPU-% caps over a
//! sliding window, enforced by masking the feasible DNN set.
//!
//! The governor answers one question per frame: *which DNNs could run
//! right now without pushing the windowed board power (or GPU
//! utilisation) over the cap?* It keeps the recent busy intervals that
//! intersect the sliding window (everything older is evicted, so state
//! is O(window / lightest-latency)) and, for each candidate DNN,
//! projects the tegrastats-style windowed mean over the window that
//! would end when that DNN's inference completes. Feasibility is a
//! conservative projection — intervals still in flight when a doomed
//! frame is presented are double-counted against the candidate — which
//! errs toward staying under the cap.
//!
//! The optional [`RateCap`] models DVFS-style frequency capping (the
//! deployment-space axis AyE-Edge searches): capping the accelerator at
//! `scale` of nominal frequency stretches every latency mean by
//! `1/scale` and cuts the active-above-idle power by `scale²`
//! (dynamic power ≈ C·V²·f with voltage tracking frequency).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::sim::latency::LatencyModel;
use crate::sim::profiles::{DnnProfile, GPU_IDLE_PCT, POWER_IDLE_W};
use crate::DnnKind;

/// Per-DNN feasibility mask, indexed by [`DnnKind::index`].
pub type DnnMask = [bool; DnnKind::COUNT];

/// A governor shared between policies (e.g. the per-stream policies of
/// one board in [`crate::coordinator::multistream`]): every wrapped
/// policy records into, and masks against, the same window.
pub type SharedBudget = Rc<RefCell<PowerBudget>>;

/// DVFS-style frequency cap: the accelerator runs at `scale` of its
/// nominal clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateCap {
    scale: f64,
}

impl RateCap {
    /// `scale` in (0, 1]: 1.0 = nominal clocks, 0.5 = half frequency.
    pub fn new(scale: f64) -> Self {
        assert!(
            scale > 0.0 && scale <= 1.0,
            "rate-cap scale must be in (0, 1], got {scale}"
        );
        RateCap { scale }
    }

    pub fn scale(self) -> f64 {
        self.scale
    }

    /// Multiplier on inference latency means (`1/scale`).
    pub fn latency_factor(self) -> f64 {
        1.0 / self.scale
    }

    /// Multiplier on active-above-idle power (`scale²`; dynamic power
    /// scales ≈ V²f with V tracking f on the Nano's DVFS ladder).
    pub fn power_factor(self) -> f64 {
        self.scale * self.scale
    }

    /// A copy of `latency` with every mean stretched by
    /// [`latency_factor`](Self::latency_factor) — the execution-side
    /// half of the cap (the governor models the same stretch).
    pub fn stretch(self, latency: &LatencyModel) -> LatencyModel {
        latency.clone().stretched(self.latency_factor())
    }
}

/// Budget configuration: caps are optional and independent.
#[derive(Debug, Clone)]
pub struct BudgetConfig {
    /// Cap on windowed mean board power, watts.
    pub watts_cap: Option<f64>,
    /// Cap on windowed mean GPU utilisation, percent.
    pub gpu_cap_pct: Option<f64>,
    /// Sliding-window length, seconds (default 1.0 — the tegrastats
    /// resolution the paper samples at).
    pub window_s: f64,
    /// Optional DVFS frequency cap folded into the governor's model.
    pub rate_cap: Option<RateCap>,
}

impl Default for BudgetConfig {
    fn default() -> Self {
        BudgetConfig {
            watts_cap: None,
            gpu_cap_pct: None,
            window_s: 1.0,
            rate_cap: None,
        }
    }
}

/// Sliding-window power/utilisation governor.
pub struct PowerBudget {
    cfg: BudgetConfig,
    /// Effective latency means, seconds (rate-cap stretched).
    lat: [f64; DnnKind::COUNT],
    /// Effective active board power, watts (rate-cap scaled).
    active_w: [f64; DnnKind::COUNT],
    /// GPU utilisation while executing, percent.
    gpu_pct: [f64; DnnKind::COUNT],
    /// Busy intervals intersecting the window, oldest first.
    recent: VecDeque<(f64, f64, DnnKind)>,
    /// Latest stream time seen.
    now: f64,
}

impl PowerBudget {
    /// Build a governor from a config and the latency model whose means
    /// drive the projections. Panics on an invalid config — CLI-facing
    /// callers go through [`PowerBudget::try_new`] instead.
    pub fn new(cfg: BudgetConfig, latency: &LatencyModel) -> Self {
        match Self::try_new(cfg, latency) {
            Ok(b) => b,
            // tod-lint: allow(srv-panic) reason="documented construction-time contract; CLI callers use try_new"
            Err(e) => panic!("invalid power budget: {e}"),
        }
    }

    /// Fallible constructor: rejects non-positive/non-finite windows
    /// and caps, and caps at or below the idle floors ([`POWER_IDLE_W`]
    /// / [`GPU_IDLE_PCT`]), which no selection could ever satisfy. Caps
    /// between the idle floor and the lightest DNN's sustained draw are
    /// accepted but best-effort: the governor throttles *which* DNN
    /// runs, never whether the stream is served, so the lightest DNN
    /// still executes when nothing is feasible.
    pub fn try_new(
        cfg: BudgetConfig,
        latency: &LatencyModel,
    ) -> Result<Self, String> {
        if !(cfg.window_s > 0.0 && cfg.window_s.is_finite()) {
            return Err(format!(
                "budget window must be positive and finite, got {}",
                cfg.window_s
            ));
        }
        for cap in [cfg.watts_cap, cfg.gpu_cap_pct].into_iter().flatten() {
            if !(cap > 0.0 && cap.is_finite()) {
                return Err(format!(
                    "budget caps must be positive and finite, got {cap}"
                ));
            }
        }
        if let Some(w) = cfg.watts_cap {
            if w <= POWER_IDLE_W {
                return Err(format!(
                    "watts cap {w} is at or below the {POWER_IDLE_W} W \
                     idle floor — no schedule can satisfy it"
                ));
            }
        }
        if let Some(g) = cfg.gpu_cap_pct {
            if g <= GPU_IDLE_PCT {
                return Err(format!(
                    "GPU cap {g}% is at or below the {GPU_IDLE_PCT}% \
                     idle floor — no schedule can satisfy it"
                ));
            }
        }
        Ok(Self::build(cfg, latency))
    }

    fn build(cfg: BudgetConfig, latency: &LatencyModel) -> Self {
        let mut lat = latency.means();
        let mut active_w =
            DnnKind::ALL.map(|k| DnnProfile::of(k).power_active_w);
        let gpu_pct = DnnKind::ALL.map(|k| DnnProfile::of(k).gpu_util_pct);
        if let Some(rc) = cfg.rate_cap {
            for l in lat.iter_mut() {
                *l *= rc.latency_factor();
            }
            for a in active_w.iter_mut() {
                *a = POWER_IDLE_W + (*a - POWER_IDLE_W) * rc.power_factor();
            }
        }
        PowerBudget {
            cfg,
            lat,
            active_w,
            gpu_pct,
            recent: VecDeque::new(),
            now: 0.0,
        }
    }

    /// Watts-only cap with the default 1 s window.
    pub fn watts(cap: f64, latency: &LatencyModel) -> Self {
        PowerBudget::new(
            BudgetConfig { watts_cap: Some(cap), ..BudgetConfig::default() },
            latency,
        )
    }

    /// GPU-%-only cap with the default 1 s window.
    pub fn gpu(cap_pct: f64, latency: &LatencyModel) -> Self {
        PowerBudget::new(
            BudgetConfig {
                gpu_cap_pct: Some(cap_pct),
                ..BudgetConfig::default()
            },
            latency,
        )
    }

    /// A governor with no caps: every DNN is always feasible.
    pub fn unbounded() -> Self {
        PowerBudget::new(
            BudgetConfig::default(),
            &LatencyModel::deterministic(),
        )
    }

    /// True when no cap is configured.
    pub fn is_unbounded(&self) -> bool {
        self.cfg.watts_cap.is_none() && self.cfg.gpu_cap_pct.is_none()
    }

    /// Wrap in the shared handle used by per-board governors.
    pub fn shared(self) -> SharedBudget {
        Rc::new(RefCell::new(self))
    }

    /// The configuration the governor runs under.
    pub fn config(&self) -> &BudgetConfig {
        &self.cfg
    }

    /// Latest stream time the governor has seen.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Intervals currently retained (bounded by the window).
    pub fn n_retained(&self) -> usize {
        self.recent.len()
    }

    /// Expected board energy of one inference, joules (effective
    /// latency × effective active power) — the tie-breaker of
    /// [`super::BudgetedPolicy`]'s energy-aware argmax.
    pub fn energy_per_frame_j(&self, dnn: DnnKind) -> f64 {
        self.lat[dnn.index()] * self.active_w[dnn.index()]
    }

    /// Effective (rate-cap stretched) latency mean, seconds.
    pub fn effective_latency_s(&self, dnn: DnnKind) -> f64 {
        self.lat[dnn.index()]
    }

    /// Advance the governor clock (monotone; evicts expired intervals).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
            self.evict();
        }
    }

    /// Record a completed busy interval (stream seconds, in completion
    /// order — both the per-stream and the serialized shared-board case
    /// deliver them monotonically).
    pub fn record(&mut self, start: f64, end: f64, dnn: DnnKind) {
        debug_assert!(end >= start, "interval ends before it starts");
        self.recent.push_back((start, end, dnn));
        self.now = self.now.max(end);
        self.evict();
    }

    fn evict(&mut self) {
        let cutoff = self.now - self.cfg.window_s;
        while let Some(&(_, e, _)) = self.recent.front() {
            if e <= cutoff {
                self.recent.pop_front();
            } else {
                break;
            }
        }
    }

    /// Projected windowed (mean power W, mean GPU %) if `dnn` started
    /// an inference at `now`, over the window ending at its completion.
    /// Windows that would start before the stream (t < 0) are clipped,
    /// so a cold start is judged over the elapsed time only.
    pub fn projected(&self, now: f64, dnn: DnnKind) -> (f64, f64) {
        let now = now.max(self.now);
        let lat = self.lat[dnn.index()];
        let end = now + lat;
        let win_start = (end - self.cfg.window_s).max(0.0);
        let len = end - win_start;
        if len <= 0.0 {
            return (POWER_IDLE_W, GPU_IDLE_PCT);
        }
        let mut above_w = lat.min(len)
            * (self.active_w[dnn.index()] - POWER_IDLE_W);
        let mut above_g =
            lat.min(len) * (self.gpu_pct[dnn.index()] - GPU_IDLE_PCT);
        for &(s, e, d) in &self.recent {
            let ov = (e.min(end) - s.max(win_start)).max(0.0);
            if ov > 0.0 {
                above_w += ov * (self.active_w[d.index()] - POWER_IDLE_W);
                above_g += ov * (self.gpu_pct[d.index()] - GPU_IDLE_PCT);
            }
        }
        (POWER_IDLE_W + above_w / len, GPU_IDLE_PCT + above_g / len)
    }

    /// Which DNNs could start an inference at `now` without breaching a
    /// cap. All-true when unbounded (and O(1) — no window scan).
    pub fn feasible(&self, now: f64) -> DnnMask {
        let mut mask = [true; DnnKind::COUNT];
        if self.is_unbounded() {
            return mask;
        }
        for k in DnnKind::ALL {
            let (w, g) = self.projected(now, k);
            let ok_w = self
                .cfg
                .watts_cap
                .map(|c| w <= c + 1e-9)
                .unwrap_or(true);
            let ok_g = self
                .cfg
                .gpu_cap_pct
                .map(|c| g <= c + 1e-9)
                .unwrap_or(true);
            mask[k.index()] = ok_w && ok_g;
        }
        mask
    }

    /// Short human-readable descriptor for policy labels.
    pub fn descriptor(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(w) = self.cfg.watts_cap {
            parts.push(format!("W<={w}"));
        }
        if let Some(g) = self.cfg.gpu_cap_pct {
            parts.push(format!("gpu<={g}%"));
        }
        if let Some(rc) = self.cfg.rate_cap {
            parts.push(format!("rate={:.2}", rc.scale()));
        }
        if parts.is_empty() {
            return "unbounded".into();
        }
        parts.push(format!("win={}s", self.cfg.window_s));
        parts.join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det() -> LatencyModel {
        LatencyModel::deterministic()
    }

    #[test]
    fn unbounded_is_always_feasible() {
        let b = PowerBudget::unbounded();
        assert!(b.is_unbounded());
        assert_eq!(b.feasible(0.0), [true; DnnKind::COUNT]);
        assert_eq!(b.feasible(123.0), [true; DnnKind::COUNT]);
        assert_eq!(b.descriptor(), "unbounded");
    }

    #[test]
    fn cold_start_masks_heavy_nets() {
        // 6.5 W cap: a window fully busy with Y-288 (7.2 W) or Y-416
        // (7.5 W) breaches; both tiny variants fit
        let b = PowerBudget::watts(6.5, &det());
        let m = b.feasible(0.0);
        assert!(m[DnnKind::TinyY288.index()]);
        assert!(m[DnnKind::TinyY416.index()]);
        assert!(!m[DnnKind::Y288.index()]);
        assert!(!m[DnnKind::Y416.index()]);
    }

    #[test]
    fn idle_history_readmits_heavy_nets() {
        // after 1 s of idle window, one 153 ms Y-416 inference projects
        // 2.6 + 0.153*4.9/1.0 ≈ 3.35 W — well under the cap
        let mut b = PowerBudget::watts(6.5, &det());
        b.advance_to(1.0);
        let m = b.feasible(1.0);
        assert_eq!(m, [true; DnnKind::COUNT]);
        let (w, _) = b.projected(1.0, DnnKind::Y416);
        assert!(w < 4.0, "projected {w}");
    }

    #[test]
    fn saturated_history_masks_everything() {
        // a window saturated with Y-416 leaves no headroom even for a
        // tiny inference
        let mut b = PowerBudget::watts(6.5, &det());
        b.record(0.0, 2.0, DnnKind::Y416);
        let m = b.feasible(2.0);
        assert_eq!(m, [false; DnnKind::COUNT]);
    }

    #[test]
    fn window_slides_past_old_load() {
        let mut b = PowerBudget::watts(6.5, &det());
        b.record(0.0, 1.0, DnnKind::Y416);
        // two windows later the load has left the window entirely
        b.advance_to(3.0);
        assert_eq!(b.feasible(3.0), [true; DnnKind::COUNT]);
        // and the expired interval was evicted
        assert_eq!(b.n_retained(), 0);
    }

    #[test]
    fn gpu_cap_masks_independently() {
        // 60% GPU cap: sustained Y-288 (84%) and Y-416 (91%) breach at
        // cold start; tiny-288 (38%) and tiny-416 (55%) fit
        let b = PowerBudget::gpu(60.0, &det());
        let m = b.feasible(0.0);
        assert!(m[DnnKind::TinyY288.index()]);
        assert!(m[DnnKind::TinyY416.index()]);
        assert!(!m[DnnKind::Y288.index()]);
        assert!(!m[DnnKind::Y416.index()]);
    }

    #[test]
    fn retained_state_is_bounded_by_window() {
        let mut b = PowerBudget::watts(5.0, &det());
        let lat = 0.027;
        let mut t = 0.0;
        for _ in 0..10_000 {
            b.record(t, t + lat, DnnKind::TinyY288);
            t += lat;
        }
        // ~window/lat intervals can overlap a 1 s window
        assert!(
            b.n_retained() <= (1.0 / lat) as usize + 2,
            "retained {}",
            b.n_retained()
        );
    }

    #[test]
    fn projection_matches_hand_computation() {
        let mut b = PowerBudget::watts(6.0, &det());
        // half the window busy with tiny-416 (4.8 W active)
        b.record(0.0, 0.5, DnnKind::TinyY416);
        b.advance_to(1.0);
        // candidate tiny-288 at t=1.0: window [0.153.., 1.027]... use
        // exact terms: lat 0.027, end 1.027, start 0.027, len 1.0;
        // history overlap = 0.5 - 0.027 = 0.473
        let (w, _) = b.projected(1.0, DnnKind::TinyY288);
        let expect = POWER_IDLE_W
            + (0.027 * (3.8 - POWER_IDLE_W)
                + 0.473 * (4.8 - POWER_IDLE_W))
                / 1.0;
        assert!((w - expect).abs() < 1e-9, "{w} vs {expect}");
    }

    #[test]
    fn rate_cap_stretches_latency_and_cuts_power() {
        let rc = RateCap::new(0.5);
        assert_eq!(rc.latency_factor(), 2.0);
        assert_eq!(rc.power_factor(), 0.25);
        let capped = PowerBudget::new(
            BudgetConfig {
                watts_cap: Some(6.0),
                rate_cap: Some(rc),
                ..BudgetConfig::default()
            },
            &det(),
        );
        let nominal = PowerBudget::watts(6.0, &det());
        assert_eq!(
            capped.effective_latency_s(DnnKind::Y416),
            2.0 * nominal.effective_latency_s(DnnKind::Y416)
        );
        // energy per frame: 2x time, 1/4 dynamic power => cheaper frame
        assert!(
            capped.energy_per_frame_j(DnnKind::Y416)
                < nominal.energy_per_frame_j(DnnKind::Y416)
        );
        // and the stretched latency model matches the governor's view
        let lat = rc.stretch(&det());
        assert_eq!(
            lat.mean(DnnKind::Y416),
            capped.effective_latency_s(DnnKind::Y416)
        );
    }

    #[test]
    fn energy_per_frame_is_monotone_in_weight() {
        let b = PowerBudget::unbounded();
        let e: Vec<f64> = DnnKind::ALL
            .iter()
            .map(|&k| b.energy_per_frame_j(k))
            .collect();
        assert!(e.windows(2).all(|w| w[0] < w[1]), "{e:?}");
    }

    #[test]
    fn descriptor_names_the_caps() {
        let b = PowerBudget::watts(6.5, &det());
        assert_eq!(b.descriptor(), "W<=6.5,win=1s");
        let g = PowerBudget::gpu(50.0, &det());
        assert!(g.descriptor().contains("gpu<=50%"));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_cap_rejected() {
        PowerBudget::watts(0.0, &det());
    }

    #[test]
    fn idle_floor_caps_rejected() {
        // 2.0 W < the 2.6 W idle floor: nothing could ever satisfy it
        let e = PowerBudget::try_new(
            BudgetConfig {
                watts_cap: Some(2.0),
                ..BudgetConfig::default()
            },
            &det(),
        );
        assert!(e.err().expect("must reject").contains("idle floor"));
        assert!(PowerBudget::try_new(
            BudgetConfig {
                gpu_cap_pct: Some(3.0),
                ..BudgetConfig::default()
            },
            &det(),
        )
        .is_err());
        // above the floor — even below the lightest DNN's sustained
        // draw — is accepted as a best-effort cap
        assert!(PowerBudget::try_new(
            BudgetConfig {
                watts_cap: Some(3.0),
                ..BudgetConfig::default()
            },
            &det(),
        )
        .is_ok());
    }

    #[test]
    #[should_panic(expected = "rate-cap scale")]
    fn rate_cap_rejects_overclock() {
        RateCap::new(1.5);
    }
}
