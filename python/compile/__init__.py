"""Build-time compile path: L1 Pallas kernels + L2 JAX detector + AOT.

Nothing in this package runs at serve time — ``aot.py`` lowers the four
detector variants to HLO text once, and the Rust runtime owns the rest.
"""
