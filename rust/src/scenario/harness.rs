//! Deterministic scenario replay: any policy × scheduler × budget ×
//! batching configuration, end to end, from a single seed.
//!
//! [`run_scenario`] drives every compiled stream of a scenario to
//! completion over one shared virtual accelerator, reusing the exact
//! per-stream state machine the production drivers use
//! ([`crate::coordinator::session::StreamSession`]) — Algorithm 1/2,
//! carry-forward, evaluation, metering all come from there. The
//! *dispatch loop* is a board-time sibling of
//! [`crate::coordinator::multistream::MultiStreamScheduler::run`]:
//! it keeps that loop's invariants (one inference at a time, RR/EDF
//! orders, occupancy-based contention, the same-DNN batching
//! continuation predicate — change one, change both) and adds what the
//! scheduler cannot express, epoch-shifted streams and per-phase
//! pricing. On top of the session it layers the scenario semantics:
//!
//! * **Churn** — a stream's frame clock starts at its `join_s` epoch;
//!   the dispatcher compares readiness/deadlines in *board* time and
//!   translates the accelerator-free floor back into stream time, so
//!   late joiners contend exactly as a camera plugged in mid-run would.
//!   Budget governors see board time through an epoch-shifting policy
//!   adapter, which lets one [`crate::power::SharedBudget`] govern
//!   streams with different epochs.
//! * **FPS sag/burst** — each phase's `fps_scale` multiplies the priced
//!   inference latency (the period-relative transform; see
//!   [`super::spec::PhaseSpec::fps_scale`]).
//! * **Day/night noise** — [`NoisyDetector`] post-filters the oracle
//!   deterministically per `(frame, dnn)`, so schedules cannot perturb
//!   what a detector "would have seen".
//! * **Batching** — the same back-to-back same-DNN continuation pricing
//!   as [`crate::coordinator::multistream::BatchingSim`], evaluated in
//!   board time across streams.
//!
//! A single-stream, single-phase, clean, uncontended scenario under the
//! default config reproduces [`crate::coordinator::scheduler::
//! run_realtime`] bit for bit (pinned in `rust/tests/scenario.rs`).

use crate::coordinator::multistream::{BatchingSim, DispatchPolicy};
use crate::coordinator::policy::{FixedPolicy, MbbsPolicy, SelectionPolicy};
use crate::coordinator::projected::ProjectedAccuracyPolicy;
use crate::coordinator::scheduler::{DetectError, Detector, OracleBackend, RunResult};
use crate::coordinator::session::{SessionEvent, StreamSession};
use crate::dataset::mot::GtEntry;
use crate::detection::Detection;
use crate::obs::{Event as ObsEvent, SharedRecorder};
use crate::power::{BudgetedPolicy, EnergyMeter, PowerBudget, PowerSummary};
use crate::predictor::CalibrationTable;
use crate::sim::latency::{ContentionModel, LatencyModel};
use crate::sim::oracle::OracleDetector;
use crate::telemetry::tegrastats::ScheduleTrace;
use crate::telemetry::utilisation::UtilisationSummary;
use crate::util::rng::Rng;
use crate::DnnKind;

use super::spec::{CompiledStream, NoiseProfile};

/// Which selection policy every stream of the run uses.
#[derive(Debug, Clone)]
pub enum PolicyKind {
    /// Algorithm 1 with the paper's `H_opt` ladder.
    Tod,
    /// Always the same DNN (the fixed baselines).
    Fixed(DnnKind),
    /// Projected-accuracy selection over a calibration table
    /// ([`HarnessConfig::table`] must be set).
    Projected,
}

/// One end-to-end configuration of the replay harness.
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    pub policy: PolicyKind,
    pub dispatch: DispatchPolicy,
    /// Board-level watts cap shared by every stream (None = ungoverned).
    pub watts_budget: Option<f64>,
    /// Cross-stream micro-batching (None = per-request dispatch).
    pub batching: Option<BatchingSim>,
    /// Contention inflation between co-resident streams.
    pub contention: ContentionModel,
    /// Latency source (deterministic for conformance runs).
    pub latency: LatencyModel,
    /// Calibration table for [`PolicyKind::Projected`] and for the
    /// energy-aware argmax when a watts budget is set on it.
    pub table: Option<CalibrationTable>,
}

impl HarnessConfig {
    fn base(policy: PolicyKind) -> Self {
        HarnessConfig {
            policy,
            dispatch: DispatchPolicy::RoundRobin,
            watts_budget: None,
            batching: None,
            contention: ContentionModel::jetson_nano(),
            latency: LatencyModel::deterministic(),
            table: None,
        }
    }

    /// Algorithm 1 with `H_opt`.
    pub fn tod() -> Self {
        Self::base(PolicyKind::Tod)
    }

    /// A fixed single-DNN deployment.
    pub fn fixed(dnn: DnnKind) -> Self {
        Self::base(PolicyKind::Fixed(dnn))
    }

    /// Projected-accuracy selection over `table`.
    pub fn projected(table: CalibrationTable) -> Self {
        let mut cfg = Self::base(PolicyKind::Projected);
        cfg.table = Some(table);
        cfg
    }

    /// Cap board power at `watts` (shared across all streams). A
    /// projected policy becomes the energy-aware argmax.
    pub fn with_watts(mut self, watts: f64) -> Self {
        assert!(
            watts > 0.0 && watts.is_finite(),
            "watts budget must be positive and finite"
        );
        self.watts_budget = Some(watts);
        self
    }

    pub fn with_dispatch(mut self, dispatch: DispatchPolicy) -> Self {
        self.dispatch = dispatch;
        self
    }

    pub fn with_batching(mut self, batching: BatchingSim) -> Self {
        self.batching = Some(batching);
        self
    }

    pub fn with_contention(mut self, contention: ContentionModel) -> Self {
        self.contention = contention;
        self
    }

    /// Canonical configuration label used in records and goldens.
    pub fn label(&self) -> String {
        let mut out = match &self.policy {
            PolicyKind::Tod => "tod".to_string(),
            PolicyKind::Fixed(k) => format!("fixed:{}", k.artifact_name()),
            PolicyKind::Projected => "projected".to_string(),
        };
        if let Some(w) = self.watts_budget {
            out.push_str(&format!("@{w}W"));
        }
        if let Some(b) = &self.batching {
            out.push_str(&format!("+batch{}", b.max_batch));
        }
        out
    }

    /// Build the per-stream policy stack (base policy, optional shared
    /// watts governor with optional clamp recorder, epoch shift).
    fn build_policy(
        &self,
        epoch: f64,
        shared: &Option<crate::power::SharedBudget>,
        obs: Option<(&SharedRecorder, u32)>,
    ) -> Result<Box<dyn SelectionPolicy>, String> {
        // attach the recorder *inside* the epoch shift: the governor's
        // hooks already see board time, so its clamps stamp correctly
        let budgeted = |p: BudgetedPolicy| -> Box<dyn SelectionPolicy> {
            match obs {
                Some((rec, stream)) => {
                    Box::new(p.with_recorder(rec.clone(), stream))
                }
                None => Box::new(p),
            }
        };
        let base: Box<dyn SelectionPolicy> = match (&self.policy, shared) {
            (PolicyKind::Tod, None) => Box::new(MbbsPolicy::tod_default()),
            (PolicyKind::Fixed(k), None) => Box::new(FixedPolicy(*k)),
            (PolicyKind::Projected, None) => {
                let table = self.table.clone().ok_or(
                    "projected policy needs a calibration table \
                     (HarnessConfig::projected)",
                )?;
                Box::new(ProjectedAccuracyPolicy::new(table, &self.latency))
            }
            (PolicyKind::Tod, Some(b)) => {
                budgeted(BudgetedPolicy::masking_shared(
                    Box::new(MbbsPolicy::tod_default()),
                    b.clone(),
                ))
            }
            (PolicyKind::Fixed(k), Some(b)) => {
                budgeted(BudgetedPolicy::masking_shared(
                    Box::new(FixedPolicy(*k)),
                    b.clone(),
                ))
            }
            (PolicyKind::Projected, Some(b)) => {
                let table = self.table.clone().ok_or(
                    "projected policy needs a calibration table \
                     (HarnessConfig::projected)",
                )?;
                budgeted(BudgetedPolicy::argmax_shared(table, b.clone()))
            }
        };
        Ok(if epoch == 0.0 {
            base
        } else {
            Box::new(EpochShift { inner: base, epoch })
        })
    }
}

/// Shifts the stream-time policy hooks by the stream's join epoch, so
/// board-level governors ([`crate::power::SharedBudget`]) see one
/// coherent clock across streams that joined at different times.
struct EpochShift {
    inner: Box<dyn SelectionPolicy>,
    epoch: f64,
}

impl SelectionPolicy for EpochShift {
    fn select(&mut self, features: &crate::features::FrameFeatures) -> DnnKind {
        self.inner.select(features)
    }

    fn label(&self) -> String {
        self.inner.label()
    }

    fn on_frame(&mut self, t_s: f64) {
        self.inner.on_frame(t_s + self.epoch);
    }

    fn on_inferred(&mut self, start_s: f64, end_s: f64, dnn: DnnKind) {
        self.inner
            .on_inferred(start_s + self.epoch, end_s + self.epoch, dnn);
    }

    fn governs(&self) -> bool {
        // forwarded so an epoch-shifted governor still gets its
        // budget_govern stage span (DESIGN.md §15)
        self.inner.governs()
    }
}

/// Deterministic day/night post-filter over any detector backend.
///
/// For a frame in a noisy phase, each detection is dropped with the
/// phase's `miss` probability and surviving confidences are attenuated
/// by `1 - conf_loss`. The random draws are a pure function of
/// `(stream seed, frame, dnn)` — the schedule a policy takes cannot
/// change what the detector would have seen, keeping comparisons
/// paired exactly like the oracle itself.
pub struct NoisyDetector<'a> {
    inner: Box<dyn Detector + 'a>,
    seed: u64,
    /// `(first_frame, profile)` per phase, ascending.
    phases: Vec<(u64, NoiseProfile)>,
}

impl<'a> NoisyDetector<'a> {
    pub fn new(
        inner: Box<dyn Detector + 'a>,
        seed: u64,
        phases: Vec<(u64, NoiseProfile)>,
    ) -> Self {
        NoisyDetector { inner, seed, phases }
    }

    /// Wrap the oracle for a compiled stream (no-op pass-through when
    /// every phase is clean).
    pub fn for_stream(stream: &CompiledStream) -> Box<dyn Detector + 'a> {
        let oracle = OracleBackend(OracleDetector::new(
            stream.seq.spec.seed,
            stream.seq.spec.width as f64,
            stream.seq.spec.height as f64,
        ));
        if stream.phases.iter().all(|p| p.noise.is_clean()) {
            return Box::new(oracle);
        }
        Box::new(NoisyDetector::new(
            Box::new(oracle),
            stream.seq.spec.seed,
            stream
                .phase_starts
                .iter()
                .zip(&stream.phases)
                .map(|(&f, p)| (f, p.noise))
                .collect(),
        ))
    }

    fn noise_at(&self, frame: u64) -> NoiseProfile {
        let i = match self.phases.binary_search_by_key(&frame, |&(f, _)| f) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        self.phases.get(i).map(|&(_, n)| n).unwrap_or(NoiseProfile::DAY)
    }
}

impl Detector for NoisyDetector<'_> {
    fn detect(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> Result<Vec<Detection>, DetectError> {
        let dets = self.inner.detect(frame, gt, dnn)?;
        let noise = self.noise_at(frame);
        if noise.is_clean() {
            return Ok(dets);
        }
        let mut rng = Rng::new(
            self.seed
                ^ frame.wrapping_mul(0x6a09e667f3bcc909)
                ^ ((dnn.index() as u64 + 1) << 48),
        );
        Ok(dets
            .into_iter()
            .filter(|_| !rng.chance(noise.miss))
            .map(|mut d| {
                d.score *= (1.0 - noise.conf_loss) as f32;
                d
            })
            .collect())
    }
}

/// One stream's outcome plus its scenario coordinates.
#[derive(Debug, Clone)]
pub struct StreamRun {
    pub label: String,
    pub join_s: f64,
    pub result: RunResult,
    /// Phase boundary metadata copied from the compiled stream (first
    /// frame + label + frame count per phase), for per-phase series.
    pub phase_starts: Vec<u64>,
    pub phase_labels: Vec<String>,
    pub phase_frames: Vec<u64>,
}

/// Everything one harness run produces.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    pub scenario: String,
    pub config: String,
    pub per_stream: Vec<StreamRun>,
    /// Board-time aggregate utilisation (traces shifted by each
    /// stream's join epoch).
    pub utilisation: UtilisationSummary,
    /// Board-level energy/power over the merged board timeline.
    pub power: PowerSummary,
}

impl ScenarioRun {
    /// Mean AP across streams.
    pub fn mean_ap(&self) -> f64 {
        if self.per_stream.is_empty() {
            return 0.0;
        }
        self.per_stream.iter().map(|s| s.result.ap).sum::<f64>()
            / self.per_stream.len() as f64
    }

    /// Aggregate drop rate over all streams' frames.
    pub fn drop_rate(&self) -> f64 {
        let frames: u64 =
            self.per_stream.iter().map(|s| s.result.n_frames).sum();
        let dropped: u64 =
            self.per_stream.iter().map(|s| s.result.n_dropped).sum();
        if frames == 0 {
            0.0
        } else {
            dropped as f64 / frames as f64
        }
    }
}

struct Slot<'a> {
    session: StreamSession<'a>,
    detector: Box<dyn Detector + 'a>,
    compiled: &'a CompiledStream,
}

/// Replay a compiled scenario under `config`. Deterministic in the
/// scenario seed and the config (conformance runs use a deterministic
/// latency model).
pub fn run_scenario(
    scenario_name: &str,
    streams: &[CompiledStream],
    config: &HarnessConfig,
) -> Result<ScenarioRun, String> {
    run_scenario_observed(scenario_name, streams, config, None)
}

/// [`run_scenario`] with an optional observability recorder: every
/// session event, budget clamp and batch formation/flush of the run is
/// emitted on the board timeline (stream ids follow `streams` order).
/// The conformance harness attaches a flight recorder here to dump the
/// tail of a failing run; `run_scenario` itself stays recorder-free so
/// golden byte-stability is untouched.
pub fn run_scenario_observed(
    scenario_name: &str,
    streams: &[CompiledStream],
    config: &HarnessConfig,
    recorder: Option<&SharedRecorder>,
) -> Result<ScenarioRun, String> {
    let emit = |ev: ObsEvent| {
        if let Some(rec) = recorder {
            rec.borrow_mut().record(&ev);
        }
    };
    let shared = config
        .watts_budget
        .map(|w| PowerBudget::watts(w, &config.latency).shared());
    let mut latency = config.latency.clone();
    let mut slots: Vec<Slot> = Vec::with_capacity(streams.len());
    for (i, c) in streams.iter().enumerate() {
        let obs = recorder.map(|rec| (rec, i as u32));
        let policy = config.build_policy(c.join_s, &shared, obs)?;
        let mut session = StreamSession::new(&c.seq, policy, c.eval_fps);
        if let Some(rec) = recorder {
            session = session.with_recorder(rec.clone(), i as u32, c.join_s);
        }
        slots.push(Slot {
            session,
            detector: NoisyDetector::for_stream(c),
            compiled: c,
        });
    }

    // board-time scheduling state
    let mut gpu_free = 0.0f64;
    let mut rr_cursor = 0usize;
    // micro-batch run state (board time)
    let mut run_dnn: Option<DnnKind> = None;
    let mut run_len = 0usize;
    let mut run_end = f64::NEG_INFINITY;

    loop {
        // streams with a frame the accelerator will actually run, in
        // board time (stream-local readiness shifted by the join epoch)
        let candidates: Vec<(usize, f64, f64)> = slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                let ready = s.compiled.join_s + s.session.next_infer_ready()?;
                let deadline =
                    s.compiled.join_s + s.session.next_infer_deadline()?;
                Some((i, ready, deadline))
            })
            .collect();
        if candidates.is_empty() {
            break;
        }
        // dispatch only among streams ready by the time the
        // accelerator frees (or the earliest-ready stream when the
        // accelerator is ahead of every arrival). Without this horizon
        // an oblivious round-robin would dispatch a stream that joins
        // seconds from now and idle the board while live streams drop.
        let earliest = candidates
            .iter()
            .map(|&(_, r, _)| r)
            .fold(f64::INFINITY, f64::min);
        let horizon = gpu_free.max(earliest) + 1e-12;
        let eligible: Vec<(usize, f64, f64)> = candidates
            .iter()
            .filter(|&&(_, r, _)| r <= horizon)
            .copied()
            .collect();
        let chosen = match config.dispatch {
            DispatchPolicy::RoundRobin => eligible
                .iter()
                .find(|(i, _, _)| *i >= rr_cursor)
                .or_else(|| eligible.first())
                .copied()
                .expect("the earliest-ready candidate is always eligible"),
            DispatchPolicy::EarliestDeadlineFirst => eligible
                .iter()
                .copied()
                .min_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)))
                .expect("the earliest-ready candidate is always eligible"),
        };
        let (idx, ready, _) = chosen;
        let start_est = gpu_free.max(ready);
        let occupancy = candidates
            .iter()
            .filter(|(_, r, _)| *r <= start_est + 1e-12)
            .count()
            .max(1);
        let inflation = config.contention.factor(occupancy);

        let slot = &mut slots[idx];
        let epoch = slot.compiled.join_s;
        loop {
            // the frame that will be inferred if this step infers (the
            // drained drops present earlier frames, which never call
            // the pricing closure)
            let infer_frame = slot.session.next_infer_frame();
            let was_cont = std::cell::Cell::new(false);
            let compiled = slot.compiled;
            let batching = &config.batching;
            let (rd, rl, re) = (run_dnn, run_len, run_end);
            let event = slot.session.step_with(
                slot.detector.as_mut(),
                &mut |dnn| {
                    let mut base = latency.sample(dnn);
                    // phase-local capture-clock scale (FPS sag/burst)
                    if let Some(f) = infer_frame {
                        let scale =
                            compiled.phases[compiled.phase_of(f)].fps_scale;
                        if scale != 1.0 {
                            base *= scale;
                        }
                    }
                    if let Some(b) = batching {
                        let cont = rd == Some(dnn)
                            && rl < b.max_batch
                            && start_est <= re + 1e-12;
                        was_cont.set(cont);
                        if cont {
                            base *= 1.0 - b.setup_frac;
                        }
                    }
                    if inflation == 1.0 {
                        base
                    } else {
                        base * inflation
                    }
                },
                gpu_free - epoch,
            );
            match event {
                SessionEvent::Inferred { dnn, interval: (start, end), .. }
                | SessionEvent::InferenceFailed {
                    dnn,
                    interval: (start, end),
                    ..
                } => {
                    let start_global = epoch + start;
                    let end_global = epoch + end;
                    if config.batching.is_some() {
                        if was_cont.get() {
                            run_len += 1;
                            emit(ObsEvent::BatchExtended {
                                stream: idx as u32,
                                dnn,
                                len: run_len as u32,
                                t: start_global,
                            });
                        } else {
                            // a new run closes the previous one
                            if let Some(prev) = run_dnn {
                                emit(ObsEvent::BatchFlushed {
                                    dnn: prev,
                                    len: run_len as u32,
                                    t: run_end,
                                });
                            }
                            run_dnn = Some(dnn);
                            run_len = 1;
                            emit(ObsEvent::BatchFormed {
                                stream: idx as u32,
                                dnn,
                                t: start_global,
                            });
                        }
                        run_end = end_global;
                    }
                    gpu_free = gpu_free.max(end_global);
                    break;
                }
                SessionEvent::Dropped { .. } => continue,
                SessionEvent::Finished => break,
            }
        }
        rr_cursor = (idx + 1) % slots.len();
    }
    // the accelerator's last micro-batch run never sees a successor
    if let Some(dnn) = run_dnn {
        emit(ObsEvent::BatchFlushed {
            dnn,
            len: run_len as u32,
            t: run_end,
        });
    }

    // drain streams whose remaining frames are all destined to drop
    for slot in &mut slots {
        let epoch = slot.compiled.join_s;
        while !slot.session.is_finished() {
            slot.session.step_with(
                slot.detector.as_mut(),
                &mut |dnn| latency.sample(dnn),
                gpu_free - epoch,
            );
        }
    }

    let per_stream: Vec<StreamRun> = slots
        .into_iter()
        .map(|s| {
            let compiled = s.compiled;
            StreamRun {
                label: compiled.label.clone(),
                join_s: compiled.join_s,
                result: s.session.finish(),
                phase_starts: compiled.phase_starts.clone(),
                phase_labels: compiled
                    .phases
                    .iter()
                    .map(|p| p.label.clone())
                    .collect(),
                phase_frames: compiled
                    .phases
                    .iter()
                    .map(|p| p.frames)
                    .collect(),
            }
        })
        .collect();

    // board-time aggregates: shift each stream's trace by its epoch
    let shifted: Vec<ScheduleTrace> = per_stream
        .iter()
        .map(|s| {
            let mut t = ScheduleTrace::default();
            for &(start, end, dnn) in &s.result.trace.busy {
                t.push(s.join_s + start, s.join_s + end, dnn);
            }
            t.duration = s.join_s + s.result.trace.duration;
            t
        })
        .collect();
    let refs: Vec<&ScheduleTrace> = shifted.iter().collect();
    let failed_busy: f64 =
        per_stream.iter().map(|s| s.result.failed_busy_s).sum();
    let utilisation = UtilisationSummary::from_traces(&refs)
        .with_failed_busy(failed_busy);
    let power = EnergyMeter::from_trace(&utilisation.merged).summary();

    Ok(ScenarioRun {
        scenario: scenario_name.to_string(),
        config: config.label(),
        per_stream,
        utilisation,
        power,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::run_realtime;
    use crate::dataset::synth::CameraMotion;
    use crate::scenario::spec::{PhaseSpec, ScenarioSpec, StreamSpec};

    fn single_clean() -> ScenarioSpec {
        ScenarioSpec::new(
            "harness-unit",
            "one clean stream",
            vec![StreamSpec::new(
                "cam0",
                vec![PhaseSpec::new("only", 90).density(6).ref_height(260.0)],
            )],
        )
        .seed(41)
    }

    #[test]
    fn clean_single_stream_matches_run_realtime_bit_for_bit() {
        let spec = single_clean();
        let streams = spec.compile().unwrap();
        let cfg = HarnessConfig::tod();
        let run = run_scenario(&spec.name, &streams, &cfg).unwrap();
        assert_eq!(run.per_stream.len(), 1);

        let seq = &streams[0].seq;
        let mut pol = MbbsPolicy::tod_default();
        let mut det = OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ));
        let mut lat = LatencyModel::deterministic();
        let legacy = run_realtime(seq, &mut pol, &mut det, &mut lat, 30.0);

        let r = &run.per_stream[0].result;
        assert_eq!(r.ap, legacy.ap);
        assert_eq!(r.dnn_series, legacy.dnn_series);
        assert_eq!(r.mbbs_series, legacy.mbbs_series);
        assert_eq!(r.trace.busy, legacy.trace.busy);
        assert_eq!(r.n_dropped, legacy.n_dropped);
    }

    #[test]
    fn harness_is_deterministic() {
        let spec = ScenarioSpec::new(
            "harness-det",
            "two streams with churn and noise",
            vec![
                StreamSpec::new(
                    "cam0",
                    vec![
                        PhaseSpec::new("day", 60),
                        PhaseSpec::new("night", 60)
                            .noise(NoiseProfile::NIGHT)
                            .fps_scale(1.3),
                    ],
                ),
                StreamSpec::new(
                    "cam1",
                    vec![PhaseSpec::new("drive", 80)
                        .camera(CameraMotion::Vehicle { flow_speed: 14.0 })],
                )
                .join_at(1.5),
            ],
        )
        .seed(5);
        let streams = spec.compile().unwrap();
        let cfg = HarnessConfig::tod().with_watts(6.5);
        let a = run_scenario(&spec.name, &streams, &cfg).unwrap();
        let b = run_scenario(&spec.name, &streams, &cfg).unwrap();
        for (x, y) in a.per_stream.iter().zip(&b.per_stream) {
            assert_eq!(x.result.ap, y.result.ap);
            assert_eq!(x.result.dnn_series, y.result.dnn_series);
            assert_eq!(x.result.trace.busy, y.result.trace.busy);
        }
        assert_eq!(a.power, b.power);
    }

    #[test]
    fn churned_stream_defers_to_its_epoch() {
        let spec = ScenarioSpec::new(
            "harness-churn",
            "late joiner",
            vec![
                StreamSpec::new("cam0", vec![PhaseSpec::new("a", 60)]),
                StreamSpec::new("cam1", vec![PhaseSpec::new("b", 60)])
                    .join_at(4.0),
            ],
        )
        .seed(9);
        let streams = spec.compile().unwrap();
        let run = run_scenario(
            &spec.name,
            &streams,
            &HarnessConfig::fixed(DnnKind::TinyY288),
        )
        .unwrap();
        // the late joiner's board-time busy intervals all start at or
        // after its epoch; the board never double-books
        let late = &run.per_stream[1];
        assert!(late
            .result
            .trace
            .busy
            .iter()
            .all(|&(s, _, _)| late.join_s + s >= 4.0 - 1e-12));
        assert!(run.utilisation.overlap_seconds() < 1e-9);
        // board makespan covers the late joiner's whole stream
        assert!(run.utilisation.makespan >= 4.0 + 60.0 / 30.0 - 1e-9);
    }

    #[test]
    fn fps_burst_phase_raises_drops() {
        let mk = |scale: f64| {
            let spec = ScenarioSpec::new(
                "harness-fps",
                "burst phase",
                vec![StreamSpec::new(
                    "cam0",
                    vec![
                        PhaseSpec::new("nominal", 80).ref_height(130.0),
                        PhaseSpec::new("burst", 80)
                            .ref_height(130.0)
                            .fps_scale(scale),
                    ],
                )],
            )
            .seed(13);
            let streams = spec.compile().unwrap();
            let run = run_scenario(
                &spec.name,
                &streams,
                &HarnessConfig::fixed(DnnKind::Y288),
            )
            .unwrap();
            run.per_stream[0].result.n_dropped
        };
        let nominal = mk(1.0);
        let burst = mk(1.6);
        let sag = mk(0.4);
        assert!(burst > nominal, "burst {burst} vs nominal {nominal}");
        assert!(sag < nominal, "sag {sag} vs nominal {nominal}");
    }

    #[test]
    fn night_noise_costs_accuracy() {
        let mk = |noise: NoiseProfile| {
            let spec = ScenarioSpec::new(
                "harness-night",
                "noise phase",
                vec![StreamSpec::new(
                    "cam0",
                    vec![PhaseSpec::new("p", 120).noise(noise)],
                )],
            )
            .seed(17);
            let streams = spec.compile().unwrap();
            run_scenario(&spec.name, &streams, &HarnessConfig::tod())
                .unwrap()
                .per_stream[0]
                .result
                .ap
        };
        let day = mk(NoiseProfile::DAY);
        let night = mk(NoiseProfile::NIGHT);
        assert!(night < day - 0.02, "night {night} vs day {day}");
    }

    #[test]
    fn watts_budget_holds_on_board_power() {
        let spec = ScenarioSpec::new(
            "harness-watts",
            "small objects lean heavy",
            vec![StreamSpec::new(
                "cam0",
                vec![PhaseSpec::new("small", 240)
                    .ref_height(120.0)
                    .density(6)],
            )],
        )
        .seed(23);
        let streams = spec.compile().unwrap();
        let free =
            run_scenario(&spec.name, &streams, &HarnessConfig::tod()).unwrap();
        let capped = run_scenario(
            &spec.name,
            &streams,
            &HarnessConfig::tod().with_watts(6.0),
        )
        .unwrap();
        assert!(free.power.avg_power_w > 6.0, "{}", free.power.avg_power_w);
        assert!(
            capped.power.avg_power_w <= 6.0 + 0.3,
            "{}",
            capped.power.avg_power_w
        );
    }

    /// A backend that always reports the same single detection, so the
    /// noise post-filter's effect is directly observable per frame.
    struct ConstDetector;
    impl Detector for ConstDetector {
        fn detect(
            &mut self,
            _frame: u64,
            _gt: &[GtEntry],
            _dnn: DnnKind,
        ) -> Result<Vec<Detection>, DetectError> {
            Ok(vec![Detection::new(
                crate::geometry::BBox::new(10.0, 10.0, 40.0, 80.0),
                0.9,
                0,
            )])
        }
    }

    #[test]
    fn noise_switches_exactly_at_phase_start() {
        // miss = 0 keeps the filter deterministic: only the confidence
        // attenuation distinguishes the noisy phase, so the boundary
        // frame semantics (first_frame is *in* its phase) are pinned
        // byte-exactly.
        let night = NoiseProfile { miss: 0.0, conf_loss: 0.5 };
        let mut det = NoisyDetector::new(
            Box::new(ConstDetector),
            7,
            vec![(1, NoiseProfile::DAY), (31, night)],
        );
        let clean = det.detect(30, &[], DnnKind::Y416).unwrap();
        assert_eq!(clean[0].score, 0.9, "frame 30 is still the clean phase");
        let noisy = det.detect(31, &[], DnnKind::Y416).unwrap();
        assert_eq!(noisy[0].score, 0.45, "frame 31 opens the noisy phase");
        let later = det.detect(70, &[], DnnKind::Y416).unwrap();
        assert_eq!(later[0].score, 0.45, "noise persists past the boundary");
    }

    /// Probe policy recording the clock values its hooks observe.
    struct ClockProbe {
        log: std::rc::Rc<std::cell::RefCell<Vec<f64>>>,
    }
    impl SelectionPolicy for ClockProbe {
        fn select(
            &mut self,
            _features: &crate::features::FrameFeatures,
        ) -> DnnKind {
            DnnKind::Y416
        }
        fn label(&self) -> String {
            "probe".into()
        }
        fn on_frame(&mut self, t_s: f64) {
            self.log.borrow_mut().push(t_s);
        }
        fn on_inferred(&mut self, start_s: f64, end_s: f64, _dnn: DnnKind) {
            self.log.borrow_mut().push(start_s);
            self.log.borrow_mut().push(end_s);
        }
    }

    #[test]
    fn epoch_shift_offsets_every_policy_clock() {
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let probe = Box::new(ClockProbe { log: log.clone() });
        let mut shifted = EpochShift { inner: probe, epoch: 2.5 };
        shifted.on_frame(1.0);
        shifted.on_inferred(1.0, 1.25, DnnKind::Y416);
        assert_eq!(*log.borrow(), vec![3.5, 3.5, 3.75]);
    }

    #[test]
    fn late_joiner_contends_in_board_time() {
        let spec = ScenarioSpec::new(
            "harness-churn",
            "late joiner",
            vec![
                StreamSpec::new(
                    "early",
                    vec![PhaseSpec::new("only", 60).density(6)],
                ),
                StreamSpec::new(
                    "late",
                    vec![PhaseSpec::new("only", 60).density(6)],
                )
                .join_at(5.0),
            ],
        )
        .seed(13);
        let streams = spec.compile().unwrap();
        let run =
            run_scenario(&spec.name, &streams, &HarnessConfig::tod()).unwrap();
        assert_eq!(run.per_stream[1].join_s, 5.0);
        // every frame of both streams is accounted for: inferred+dropped
        for s in &run.per_stream {
            assert_eq!(
                s.result.n_inferred + s.result.n_dropped,
                s.result.n_frames
            );
            assert_eq!(s.result.n_frames, 60);
        }
        // board timeline extends past the late stream's join epoch, and
        // no board-time busy interval of the late stream precedes it
        assert!(run.utilisation.makespan >= 5.0);
        let late = &run.per_stream[1];
        for &(start, _, _) in &late.result.trace.busy {
            assert!(
                late.join_s + start >= 5.0 - 1e-9,
                "late stream ran at board {start}"
            );
        }
    }

    #[test]
    fn config_labels_are_canonical() {
        assert_eq!(HarnessConfig::tod().label(), "tod");
        assert_eq!(
            HarnessConfig::fixed(DnnKind::Y416).label(),
            "fixed:yolov4-416"
        );
        assert_eq!(
            HarnessConfig::tod().with_watts(6.5).label(),
            "tod@6.5W"
        );
        assert_eq!(
            HarnessConfig::tod()
                .with_batching(BatchingSim::jetson_nano(4))
                .label(),
            "tod+batch4"
        );
    }
}
