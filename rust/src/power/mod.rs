//! Energy/utilisation governance: online metering, budget enforcement
//! and power-aware DNN selection.
//!
//! The paper's resource headline (§IV.D, Figs. 13–15) is that TOD
//! matches YOLOv4-416 accuracy on MOT17-05 while using **45.1% of the
//! GPU resource and 62.7% of the board power**. This module makes that
//! axis a first-class, *enforceable* quantity instead of a post-hoc
//! figure:
//!
//! * [`EnergyMeter`] / [`PowerSummary`] — incremental joules, average
//!   watts, GPU-busy fraction and per-DNN energy, folded interval by
//!   interval as a [`crate::coordinator::session::StreamSession`]
//!   steps (and reproducible post-hoc from any
//!   [`crate::telemetry::tegrastats::ScheduleTrace`]).
//! * [`PowerBudget`] — a sliding-window governor that masks the DNNs
//!   whose execution would push windowed mean power (watts cap) or GPU
//!   utilisation (percent cap) over budget, optionally under a
//!   DVFS-style [`RateCap`] (stretched latencies, `scale²` dynamic
//!   power).
//! * [`BudgetedPolicy`] — composes the mask with any selection policy
//!   (demotion semantics), or runs an energy-aware argmax over a
//!   calibrated table: highest projected AP within budget, ties broken
//!   by lowest energy per frame.
//!
//! Entry points: `tod run --watts-budget/--gpu-budget`, `tod power`,
//! `tod figures --id power`, `Campaign::power_budgeted`,
//! `benches/power.rs` and `examples/power_budget.rs`. See DESIGN.md
//! §10.

// Serving zone (lint-policy.json): the budget governor gates every
// frame's DNN choice; metering folds into the live session loop.
// Tests are exempt via clippy.toml.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod budget;
pub mod meter;
pub mod policy;

pub use budget::{
    BudgetConfig, DnnMask, PowerBudget, RateCap, SharedBudget,
};
pub use meter::{EnergyMeter, PowerSummary};
pub use policy::BudgetedPolicy;
