//! Fixed-FPS video plumbing: the virtual frame clock and the Algorithm 2
//! drop-frame accounting (the GStreamer appsink `drop=true` analog the
//! paper uses, §III.B.2).

pub mod clock;
pub mod dropframe;
pub mod source;

pub use clock::FrameClock;
pub use dropframe::{DropFrameAccounting, FrameOutcome};
pub use source::FrameSource;
