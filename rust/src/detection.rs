//! Detections, confidence filtering, NMS, and the MBBS statistic that
//! drives the TOD policy.

use crate::geometry::BBox;

/// The class id we care about ('person'), matching the paper's filter.
pub const PERSON_CLASS: u32 = 0;

/// Confidence threshold the paper applies to YOLO outputs (§III.B.1).
pub const SCORE_THRESHOLD: f32 = 0.35;

/// One detected object in a frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Detection {
    pub bbox: BBox,
    pub score: f32,
    pub class_id: u32,
}

impl Detection {
    pub fn new(bbox: BBox, score: f32, class_id: u32) -> Self {
        Detection { bbox, score, class_id }
    }
}

/// All detections for one frame, tagged with the frame id (1-based,
/// MOT convention).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FrameDetections {
    pub frame: u64,
    pub detections: Vec<Detection>,
}

impl FrameDetections {
    pub fn new(frame: u64) -> Self {
        FrameDetections { frame, detections: Vec::new() }
    }

    /// Keep only 'person' detections above the paper's 0.35 threshold.
    pub fn filtered(&self) -> FrameDetections {
        let mut out = Vec::with_capacity(self.detections.len());
        filter_detections_into(&self.detections, &mut out);
        FrameDetections { frame: self.frame, detections: out }
    }
}

/// The paper's §III.B.1 keep predicate ('person' above 0.35), shared by
/// every filter path so the threshold semantics live in one place.
#[inline]
pub fn passes_score_filter(d: &Detection) -> bool {
    d.class_id == PERSON_CLASS && d.score > SCORE_THRESHOLD
}

/// Filter `src` into `out` (cleared first). The steady-state form of
/// [`FrameDetections::filtered`]: with a warm `out` buffer this never
/// touches the allocator.
pub fn filter_detections_into(src: &[Detection], out: &mut Vec<Detection>) {
    out.clear();
    out.extend(src.iter().copied().filter(passes_score_filter));
}

/// Descending-confidence ordering with NaN ranked *last*.
///
/// A NaN score carries no confidence information, so it must lose
/// every comparison: ranked first (as raw `total_cmp` would put it) a
/// NaN-scored box would claim ground truth in matching and suppress
/// genuinely confident boxes in NMS — one bad tensor value erasing
/// valid detections. Ranked last, the damage stays confined to the
/// NaN detection itself (and the score filter drops it anyway).
pub fn by_score_desc_nan_last(a: f32, b: f32) -> std::cmp::Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater, // a sorts after b
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => b.total_cmp(&a),
    }
}

/// Median of Bounding-Box Sizes as a fraction of the frame area — the
/// paper's per-frame signal (§III.B.3). Returns 0.0 when there are no
/// boxes, which routes Algorithm 1 to the heaviest DNN (its `else`
/// branch), matching the paper's `median(bboxes)_0 = 0` initialisation.
pub fn mbbs(dets: &[Detection], frame_w: f64, frame_h: f64) -> f64 {
    let mut areas = Vec::with_capacity(dets.len());
    mbbs_with_scratch(dets, frame_w, frame_h, &mut areas)
}

/// [`mbbs`] writing its area working set into a caller-owned buffer —
/// the steady-state form used by the per-frame feature path (zero
/// allocations once the scratch has warmed to the stream's density).
pub fn mbbs_with_scratch(
    dets: &[Detection],
    frame_w: f64,
    frame_h: f64,
    areas: &mut Vec<f64>,
) -> f64 {
    if dets.is_empty() {
        return 0.0;
    }
    areas.clear();
    areas.extend(dets.iter().map(|d| d.bbox.area_frac(frame_w, frame_h)));
    // In-place O(n) selection; no allocation beyond the areas scratch.
    // total_cmp: a NaN area (degenerate box from a broken decode) must
    // not abort the serving loop — it sorts above +inf deterministically.
    let mid = areas.len() / 2;
    let (_, m, _) =
        areas.select_nth_unstable_by(mid, |a, b| a.total_cmp(b));
    let hi = *m;
    if areas.len() % 2 == 1 {
        hi
    } else {
        let lo = areas[..mid]
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max);
        (lo + hi) / 2.0
    }
}

/// Greedy non-maximum suppression: keep the highest-scoring box, drop
/// everything overlapping it above `iou_thresh`, repeat. Detections with
/// different class ids never suppress each other.
///
/// Implementation: sort-once by score, then test each candidate against
/// the *kept* set only (O(n·k) instead of the textbook O(n²) suppressed-
/// flag sweep) with a struct-of-arrays x-interval prefilter that rejects
/// most pairs on a single compare before paying for a full IoU. Both
/// formulations keep a candidate iff no earlier-kept same-class box
/// overlaps it above the threshold, so the keep set and its order are
/// bit-identical — pinned by `nms_matches_reference_on_random_inputs`.
pub fn nms(dets: &[Detection], iou_thresh: f64) -> Vec<Detection> {
    // tod-lint: allow(hot-collect) reason="sort-order index buffer sized by with_capacity-equivalent range collect; counting-allocator bench pins total allocs/op"
    let mut order: Vec<usize> = (0..dets.len()).collect();
    // NaN-safe descending score order; NaN ranks last so it can never
    // suppress a genuinely confident box. Unstable sort with an index
    // tie-break: allocation-free, same order as the reference's stable
    // sort on equal scores.
    order.sort_unstable_by(|&a, &b| {
        by_score_desc_nan_last(dets[a].score, dets[b].score)
            .then(a.cmp(&b))
    });
    let mut keep: Vec<Detection> = Vec::with_capacity(dets.len());
    // Flat kept-set arrays for the prefilter: original index plus the
    // x-interval (kept in sync with `keep`).
    let mut kept_idx: Vec<usize> = Vec::with_capacity(dets.len());
    let mut kept_x1: Vec<f64> = Vec::with_capacity(dets.len());
    let mut kept_x2: Vec<f64> = Vec::with_capacity(dets.len());
    // Disjoint x-intervals force intersection = 0 and hence iou == 0.0
    // exactly, which only fails to suppress when the threshold is
    // non-negative — with a (nonsensical) negative threshold every pair
    // suppresses, so take the exact path.
    let can_prefilter = iou_thresh >= 0.0;
    for &i in &order {
        let cand = &dets[i].bbox;
        let (x1, x2) = (cand.x, cand.right());
        let mut suppressed = false;
        for k in 0..keep.len() {
            if keep[k].class_id != dets[i].class_id {
                continue;
            }
            // NaN coordinates fail both compares and fall through to
            // the exact IoU, so the fast path never changes behaviour.
            if can_prefilter && (kept_x2[k] <= x1 || kept_x1[k] >= x2) {
                continue;
            }
            // kept.iou(candidate): the reference's operand order.
            if dets[kept_idx[k]].bbox.iou(cand) > iou_thresh {
                suppressed = true;
                break;
            }
        }
        if !suppressed {
            keep.push(dets[i]);
            kept_idx.push(i);
            kept_x1.push(x1);
            kept_x2.push(x2);
        }
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{Gen, PropConfig};

    fn det(x: f64, y: f64, w: f64, h: f64, score: f32) -> Detection {
        Detection::new(BBox::new(x, y, w, h), score, PERSON_CLASS)
    }

    /// The pre-optimisation suppressed-flag NMS, kept verbatim as the
    /// equivalence oracle for the SoA keep-list implementation.
    fn nms_reference(dets: &[Detection], iou_thresh: f64) -> Vec<Detection> {
        let mut order: Vec<usize> = (0..dets.len()).collect();
        order.sort_by(|&a, &b| {
            by_score_desc_nan_last(dets[a].score, dets[b].score)
        });
        let mut keep: Vec<Detection> = Vec::with_capacity(dets.len());
        let mut suppressed = vec![false; dets.len()];
        for (rank, &i) in order.iter().enumerate() {
            if suppressed[i] {
                continue;
            }
            keep.push(dets[i]);
            for &j in &order[rank + 1..] {
                if suppressed[j] || dets[j].class_id != dets[i].class_id {
                    continue;
                }
                if dets[i].bbox.iou(&dets[j].bbox) > iou_thresh {
                    suppressed[j] = true;
                }
            }
        }
        keep
    }

    /// Random detection set with NaN scores, NaN coordinates, negative
    /// (degenerate) extents and mixed classes.
    fn gen_dets(g: &mut Gen, max_n: usize) -> Vec<Detection> {
        let n = g.usize_in(0, max_n);
        (0..n)
            .map(|_| {
                let mut x = g.f64_in(-20.0, 100.0);
                let y = g.f64_in(-20.0, 100.0);
                let w = g.f64_in(-5.0, 40.0);
                let h = g.f64_in(-5.0, 40.0);
                if g.usize_in(0, 19) == 0 {
                    x = f64::NAN;
                }
                let score = if g.usize_in(0, 9) == 0 {
                    f32::NAN
                } else {
                    g.f64_in(0.0, 1.0) as f32
                };
                let class = g.usize_in(0, 2) as u32;
                Detection::new(BBox::new(x, y, w, h), score, class)
            })
            .collect()
    }

    #[test]
    fn nms_matches_reference_on_random_inputs() {
        PropConfig::default().run("nms == nms_reference", |g| {
            let dets = gen_dets(g, 40);
            // include a (nonsensical) negative threshold so the
            // prefilter-disabled branch is exercised too
            let thresh = g.f64_in(-0.2, 1.1);
            nms(&dets, thresh) == nms_reference(&dets, thresh)
        });
    }

    #[test]
    fn mbbs_scratch_matches_allocating_form() {
        PropConfig::default().run("mbbs_with_scratch == mbbs", |g| {
            let dets = gen_dets(g, 30);
            let mut scratch = Vec::new();
            // reuse the scratch across both calls: stale contents from
            // the first call must not leak into the second
            let a = mbbs_with_scratch(&dets, 1920.0, 1080.0, &mut scratch);
            let b = mbbs_with_scratch(&dets, 1920.0, 1080.0, &mut scratch);
            let c = mbbs(&dets, 1920.0, 1080.0);
            (a.is_nan() && b.is_nan() && c.is_nan())
                || (a == b && b == c)
        });
    }

    #[test]
    fn filter_into_matches_filtered() {
        PropConfig::default().run("filter_into == filtered", |g| {
            let dets = gen_dets(g, 30);
            let fd = FrameDetections { frame: 1, detections: dets };
            let mut out = vec![det(0.0, 0.0, 1.0, 1.0, 0.9)]; // stale
            filter_detections_into(&fd.detections, &mut out);
            out == fd.filtered().detections
        });
    }

    #[test]
    fn mbbs_empty_is_zero() {
        assert_eq!(mbbs(&[], 1920.0, 1080.0), 0.0);
    }

    #[test]
    fn mbbs_single_box() {
        let d = det(0., 0., 192., 108., 0.9);
        // 192*108 / (1920*1080) = 0.01
        assert!((mbbs(&[d], 1920., 1080.) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn mbbs_is_median_not_mean() {
        // paper's motivation: a full-frame false positive must not move
        // the statistic much
        let mut dets = vec![
            det(0., 0., 100., 100., 0.9),
            det(0., 0., 110., 100., 0.9),
            det(0., 0., 120., 100., 0.9),
        ];
        let m0 = mbbs(&dets, 1000., 1000.);
        dets.push(det(0., 0., 1000., 1000., 0.9)); // frame-sized FP
        let m1 = mbbs(&dets, 1000., 1000.);
        assert!((m0 - 0.011).abs() < 1e-9);
        assert!(m1 < 0.02, "median dragged too far: {m1}");
    }

    #[test]
    fn mbbs_even_count_averages_middle_pair() {
        let dets = vec![
            det(0., 0., 10., 10., 0.9),   // 1e-4
            det(0., 0., 20., 10., 0.9),   // 2e-4
            det(0., 0., 30., 10., 0.9),   // 3e-4
            det(0., 0., 40., 10., 0.9),   // 4e-4
        ];
        assert!((mbbs(&dets, 1000., 1000.) - 2.5e-4).abs() < 1e-12);
    }

    #[test]
    fn filter_drops_low_score_and_other_classes() {
        let mut fd = FrameDetections::new(1);
        fd.detections.push(det(0., 0., 10., 10., 0.9));
        fd.detections.push(det(0., 0., 10., 10., 0.2)); // low score
        fd.detections.push(Detection::new(
            BBox::new(0., 0., 10., 10.),
            0.9,
            7, // not a person
        ));
        let f = fd.filtered();
        assert_eq!(f.detections.len(), 1);
        assert_eq!(f.frame, 1);
    }

    #[test]
    fn filter_threshold_is_exclusive() {
        let mut fd = FrameDetections::new(1);
        fd.detections.push(det(0., 0., 10., 10., SCORE_THRESHOLD));
        assert!(fd.filtered().detections.is_empty());
    }

    #[test]
    fn nms_keeps_highest_and_drops_overlap() {
        let dets = vec![
            det(0., 0., 10., 10., 0.8),
            det(1., 1., 10., 10., 0.9), // overlaps the first, higher score
            det(50., 50., 10., 10., 0.7),
        ];
        let kept = nms(&dets, 0.5);
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].score, 0.9);
        assert_eq!(kept[1].score, 0.7);
    }

    #[test]
    fn nms_respects_class_boundaries() {
        let a = det(0., 0., 10., 10., 0.9);
        let mut b = det(0., 0., 10., 10., 0.8);
        b.class_id = 3;
        let kept = nms(&[a, b], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn nms_is_idempotent() {
        let dets = vec![
            det(0., 0., 10., 10., 0.8),
            det(2., 2., 10., 10., 0.9),
            det(4., 0., 10., 10., 0.85),
            det(100., 100., 10., 10., 0.5),
        ];
        let once = nms(&dets, 0.45);
        let twice = nms(&once, 0.45);
        assert_eq!(once, twice);
    }

    #[test]
    fn nms_empty_input() {
        assert!(nms(&[], 0.5).is_empty());
    }

    #[test]
    fn nan_score_does_not_panic_nms_or_mbbs() {
        // a detector emitting one NaN score must not abort the pipeline
        let dets = vec![
            det(0., 0., 10., 10., 0.8),
            det(50., 50., 10., 10., f32::NAN),
            det(100., 100., 10., 10., 0.6),
        ];
        let kept = nms(&dets, 0.5);
        assert_eq!(kept.len(), 3);
        let m = mbbs(&dets, 1000., 1000.);
        assert!(m.is_finite());
    }

    #[test]
    fn nan_score_cannot_suppress_valid_detections() {
        // regression: NaN must rank last, so a NaN-scored box never
        // claims NMS priority over a genuinely confident overlap (the
        // score filter then removes the NaN box, so damage from one
        // bad tensor value stays confined to that detection)
        let dets = vec![
            det(1., 1., 10., 10., f32::NAN),
            det(0., 0., 10., 10., 0.9),
        ];
        let kept = nms(&dets, 0.5);
        assert_eq!(kept.len(), 1, "NaN box must be the suppressed one");
        assert_eq!(kept[0].score, 0.9);
        use std::cmp::Ordering;
        assert_eq!(by_score_desc_nan_last(0.1, f32::NAN), Ordering::Less);
        assert_eq!(
            by_score_desc_nan_last(f32::NAN, 0.1),
            Ordering::Greater
        );
        assert_eq!(
            by_score_desc_nan_last(f32::NAN, f32::NAN),
            Ordering::Equal
        );
        assert_eq!(by_score_desc_nan_last(0.9, 0.1), Ordering::Less);
    }
}
