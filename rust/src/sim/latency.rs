//! Virtual-clock inference latency model, calibrated to the paper's
//! Fig. 5 Jetson Nano measurements.
//!
//! Algorithm 2's drop-frame behaviour depends only on the *ratio* of
//! inference latency to the frame period; replaying the paper's measured
//! latencies on a virtual clock reproduces its real-time regime exactly
//! and deterministically, independent of this machine's CPU (DESIGN.md
//! §3). Real CPU-PJRT latencies are measured separately by the
//! `runtime_infer` bench and `tod figures --id fig5`.

use crate::sim::profiles::DnnProfile;
use crate::util::rng::Rng;
use crate::DnnKind;

/// Latency source for the scheduler's virtual clock.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    profiles: [DnnProfile; 4],
    /// When false, jitter is disabled and `sample` returns the mean.
    jitter: bool,
    rng: Rng,
}

impl LatencyModel {
    /// Jetson-Nano-calibrated model with multiplicative jitter.
    pub fn jetson_nano(seed: u64) -> Self {
        LatencyModel {
            profiles: [
                DnnProfile::of(DnnKind::TinyY288),
                DnnProfile::of(DnnKind::TinyY416),
                DnnProfile::of(DnnKind::Y288),
                DnnProfile::of(DnnKind::Y416),
            ],
            jitter: true,
            rng: Rng::new(seed ^ 0x1a7e_0c10),
        }
    }

    /// Deterministic model (mean latency, no jitter) — used by tests and
    /// by the paired policy comparisons of Table I.
    pub fn deterministic() -> Self {
        let mut m = Self::jetson_nano(0);
        m.jitter = false;
        m
    }

    /// Mean latency of a variant, seconds.
    pub fn mean(&self, dnn: DnnKind) -> f64 {
        self.profiles[dnn.index()].latency_mean_s
    }

    /// Sample one inference latency, seconds.
    pub fn sample(&mut self, dnn: DnnKind) -> f64 {
        let p = &self.profiles[dnn.index()];
        if !self.jitter {
            return p.latency_mean_s;
        }
        // lognormal-ish multiplicative jitter, clamped to ±4σ
        let f = (1.0
            + self
                .rng
                .normal(0.0, p.latency_jitter)
                .clamp(-4.0 * p.latency_jitter, 4.0 * p.latency_jitter))
        .max(0.5);
        p.latency_mean_s * f
    }

    /// Does the variant meet a frame budget of `1/fps` on average?
    pub fn meets_realtime(&self, dnn: DnnKind, fps: f64) -> bool {
        self.mean(dnn) <= 1.0 / fps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_returns_mean() {
        let mut m = LatencyModel::deterministic();
        for d in DnnKind::ALL {
            assert_eq!(m.sample(d), m.mean(d));
        }
    }

    #[test]
    fn jitter_centres_on_mean() {
        let mut m = LatencyModel::jetson_nano(42);
        let n = 5000;
        let mean_sample: f64 =
            (0..n).map(|_| m.sample(DnnKind::Y416)).sum::<f64>() / n as f64;
        let mean = m.mean(DnnKind::Y416);
        assert!((mean_sample / mean - 1.0).abs() < 0.02);
    }

    #[test]
    fn samples_are_positive_and_bounded() {
        let mut m = LatencyModel::jetson_nano(7);
        for _ in 0..2000 {
            let v = m.sample(DnnKind::TinyY288);
            assert!(v > 0.0);
            assert!(v < m.mean(DnnKind::TinyY288) * 2.0);
        }
    }

    #[test]
    fn realtime_budget_matches_paper() {
        let m = LatencyModel::deterministic();
        // 30 FPS: only tiny-288 (Fig. 5)
        assert!(m.meets_realtime(DnnKind::TinyY288, 30.0));
        assert!(!m.meets_realtime(DnnKind::TinyY416, 30.0));
        assert!(!m.meets_realtime(DnnKind::Y288, 30.0));
        assert!(!m.meets_realtime(DnnKind::Y416, 30.0));
        // 14 FPS (MOT17-05): both tiny variants fit
        assert!(m.meets_realtime(DnnKind::TinyY416, 14.0));
        assert!(!m.meets_realtime(DnnKind::Y288, 14.0));
    }
}
