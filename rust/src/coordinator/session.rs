//! Resumable per-stream scheduling: the [`StreamSession`] state machine.
//!
//! The original `run_realtime` loop owned everything for exactly one
//! stream — policy, Algorithm 2 drop accounting, carried detections,
//! MBBS/DNN series and evaluation state — and ran it to completion in
//! one call. That shape can never serve two cameras from one
//! accelerator. `StreamSession` is the same loop body turned inside
//! out: all per-stream state lives in the session, and one frame is
//! advanced per [`StreamSession::step`] call, returning a
//! [`SessionEvent`] that tells the caller what the stream just did.
//!
//! Single-stream drivers ([`super::scheduler::run_realtime`]) simply
//! step a session to completion and produce the identical
//! [`RunResult`] the monolithic loop produced. Multi-stream drivers
//! ([`super::multistream::MultiStreamScheduler`]) interleave many
//! sessions in virtual time, passing each step the timestamp at which
//! the shared accelerator becomes free plus a contention-dependent
//! latency inflation factor.

use crate::dataset::synth::Sequence;
use crate::detection::{filter_detections_into, Detection};
use crate::eval::ap::{ApMethod, SequenceEval};
use crate::eval::matching::{FrameMatcher, IOU_THRESHOLD};
use crate::features::FeatureExtractor;
use crate::obs::{Event as ObsEvent, SharedRecorder, SpanArena, SpanKind};
use crate::power::{EnergyMeter, PowerSummary};
use crate::sim::latency::LatencyModel;
use crate::telemetry::tegrastats::ScheduleTrace;
use crate::video::clock::FrameClock;
use crate::video::dropframe::{DropFrameAccounting, FrameOutcome};
use crate::DnnKind;

use super::policy::SelectionPolicy;
use super::scheduler::{Detector, RunResult};

/// What one [`StreamSession::step`] did with the stream's next frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SessionEvent {
    /// The DNN ran on this frame; `interval` is the accelerator-busy
    /// window in stream seconds.
    Inferred { frame: u64, dnn: DnnKind, interval: (f64, f64) },
    /// The DNN ran (accelerator time was spent over `interval`) but the
    /// backend reported an error: the previous detections carry forward
    /// and the failure is counted, never panicked on.
    InferenceFailed { frame: u64, dnn: DnnKind, interval: (f64, f64) },
    /// The accelerator was still busy; the previous detections carry
    /// forward (Algorithm 2).
    Dropped { frame: u64 },
    /// Every frame of the sequence has been presented.
    Finished,
}

/// Resumable state machine for scheduling one stream.
///
/// Owns the stream's selection policy, Algorithm 2 accounting, carried
/// detections (the paper's `pre-boxes`), MBBS/DNN series, busy-interval
/// trace and pooled evaluation state. Frames advance one at a time via
/// [`step`](StreamSession::step); [`finish`](StreamSession::finish)
/// closes the stream and yields the [`RunResult`].
pub struct StreamSession<'a> {
    seq: &'a Sequence,
    policy: Box<dyn SelectionPolicy + 'a>,
    eval_fps: f64,
    clock: FrameClock,
    acc: DropFrameAccounting,
    eval: SequenceEval,
    trace: ScheduleTrace,
    deploy: [u64; DnnKind::COUNT],
    switches: u64,
    last_dnn: Option<DnnKind>,
    mbbs_series: Vec<f64>,
    dnn_series: Vec<Option<DnnKind>>,
    carried: Vec<Detection>,
    /// Incremental stream-feature state (MBBS + speed estimation).
    features: FeatureExtractor,
    /// Online energy/utilisation accounting (folded per step, not
    /// post-hoc — see [`crate::power::EnergyMeter`]).
    meter: EnergyMeter,
    /// Inferences whose backend reported an error (detections carried
    /// forward instead).
    n_failed: u64,
    /// 1-based id of the next frame to present.
    next_frame: u64,
    /// Raw-detection scratch the backend fills each inference; with the
    /// matcher below it makes the steady-state [`step`](Self::step)
    /// allocation-free (see `tests/perf_alloc.rs`).
    detect_buf: Vec<Detection>,
    /// Reusable greedy-matching scratch for per-frame evaluation.
    matcher: FrameMatcher,
    /// Observability sink; `None` (the default) keeps the hot path at
    /// a single branch per emission site.
    recorder: Option<SharedRecorder>,
    /// Stream id stamped on emitted events.
    obs_stream: u32,
    /// Board-time offset added to every emitted timestamp, so epoch-
    /// shifted streams share one timeline in multi-stream traces.
    obs_epoch: f64,
    /// Per-stream span ids + open-span stack (DESIGN.md §15). Only
    /// touched when a recorder is attached, so the unobserved hot path
    /// pays nothing beyond the existing branch.
    spans: SpanArena,
    /// Accelerator-busy seconds spent on inferences that then failed.
    failed_busy_s: f64,
}

impl<'a> StreamSession<'a> {
    /// Open a session over `seq` evaluated at `eval_fps`.
    pub fn new<P>(seq: &'a Sequence, policy: P, eval_fps: f64) -> Self
    where
        P: SelectionPolicy + 'a,
    {
        let n = seq.n_frames() as usize;
        // Pre-size the run-long accumulators so steady-state stepping
        // never grows them: scored pairs are bounded by the ground
        // truth the detector can hit plus a false-positive margin, and
        // the trace holds at most one busy interval per frame.
        let mut eval = SequenceEval::new();
        let total_gt: usize = (1..=seq.n_frames()).map(|f| seq.gt(f).len()).sum();
        eval.reserve(total_gt + n * 8);
        let mut trace = ScheduleTrace::default();
        trace.busy.reserve(n);
        StreamSession {
            seq,
            policy: Box::new(policy),
            eval_fps,
            clock: FrameClock::new(eval_fps),
            acc: DropFrameAccounting::new(eval_fps),
            eval,
            trace,
            deploy: [0; DnnKind::COUNT],
            switches: 0,
            last_dnn: None,
            mbbs_series: Vec::with_capacity(n),
            dnn_series: Vec::with_capacity(n),
            carried: Vec::new(),
            features: FeatureExtractor::new(
                seq.spec.width as f64,
                seq.spec.height as f64,
            ),
            meter: EnergyMeter::new(),
            n_failed: 0,
            next_frame: 1,
            detect_buf: Vec::new(),
            matcher: FrameMatcher::new(),
            recorder: None,
            obs_stream: 0,
            obs_epoch: 0.0,
            spans: SpanArena::new(),
            failed_busy_s: 0.0,
        }
    }

    /// Attach an observability recorder: events are stamped with
    /// `stream` and shifted by `epoch` (the stream's join time on the
    /// board clock; 0.0 for single-stream runs). Emits
    /// [`ObsEvent::StreamJoined`] immediately, then opens the stream's
    /// root span (span id 1; closed by [`StreamSession::finish`]).
    pub fn with_recorder(
        mut self,
        recorder: SharedRecorder,
        stream: u32,
        epoch: f64,
    ) -> Self {
        recorder
            .borrow_mut()
            .record(&ObsEvent::StreamJoined { stream, t: epoch });
        self.recorder = Some(recorder);
        self.obs_stream = stream;
        self.obs_epoch = epoch;
        self.span_open(0, SpanKind::Stream, 0.0);
        self
    }

    /// Record `ev` if a recorder is attached (one branch otherwise).
    #[inline]
    fn emit(&self, ev: ObsEvent) {
        if let Some(rec) = &self.recorder {
            rec.borrow_mut().record(&ev);
        }
    }

    /// Open a span at stream time `t` (frame 0 = not frame-scoped).
    /// No-op without a recorder, so the arena only moves when someone
    /// is listening.
    #[inline]
    fn span_open(&mut self, frame: u64, kind: SpanKind, t: f64) {
        if self.recorder.is_some() {
            let (span, parent) = self.spans.open();
            self.emit(ObsEvent::SpanOpen {
                stream: self.obs_stream,
                frame,
                span,
                parent,
                kind,
                t: t + self.obs_epoch,
            });
        }
    }

    /// Close the innermost open span at stream time `t`.
    #[inline]
    fn span_close(&mut self, t: f64) {
        if self.recorder.is_some() {
            let span = self.spans.close();
            self.emit(ObsEvent::SpanClose {
                stream: self.obs_stream,
                span,
                t: t + self.obs_epoch,
            });
        }
    }

    /// Emit a zero-width stage span (open + close at `t`). Selector-side
    /// stages cost the simulation no virtual time — the paper's
    /// "negligible overhead" — so they appear as instants whose
    /// self-time is exactly 0.
    #[inline]
    fn span_instant(&mut self, frame: u64, kind: SpanKind, t: f64) {
        if self.recorder.is_some() {
            let (span, parent) = self.spans.instant();
            let t = t + self.obs_epoch;
            self.emit(ObsEvent::SpanOpen {
                stream: self.obs_stream,
                frame,
                span,
                parent,
                kind,
                t,
            });
            self.emit(ObsEvent::SpanClose {
                stream: self.obs_stream,
                span,
                t,
            });
        }
    }

    /// The stream's label (sequence name).
    pub fn sequence_name(&self) -> &str {
        &self.seq.spec.name
    }

    /// Evaluation FPS this session runs under.
    pub fn eval_fps(&self) -> f64 {
        self.eval_fps
    }

    /// True once every frame has been presented.
    pub fn is_finished(&self) -> bool {
        self.next_frame > self.seq.n_frames()
    }

    /// Frames not yet presented.
    pub fn frames_remaining(&self) -> u64 {
        self.seq.n_frames().saturating_sub(self.next_frame - 1)
    }

    /// The next frame that would actually be *inferred* (not dropped),
    /// or `None` when every remaining frame is already destined to drop
    /// (or the stream is finished).
    pub fn next_infer_frame(&self) -> Option<u64> {
        let f = self.next_frame.max(self.acc.next_eligible());
        if f > self.seq.n_frames() {
            None
        } else {
            Some(f)
        }
    }

    /// Earliest stream time at which the next inference could start
    /// (the capture start of [`next_infer_frame`](Self::next_infer_frame)).
    pub fn next_infer_ready(&self) -> Option<f64> {
        self.next_infer_frame()
            .map(|f| self.clock.arrival(f) - self.clock.period())
    }

    /// Deadline of the next inferable frame: the moment it is superseded
    /// by its successor's arrival (used by EDF dispatch).
    pub fn next_infer_deadline(&self) -> Option<f64> {
        self.next_infer_frame()
            .map(|f| self.clock.arrival(f) + self.clock.period())
    }

    /// Busy intervals recorded so far.
    pub fn trace(&self) -> &ScheduleTrace {
        &self.trace
    }

    /// Inferences performed so far.
    pub fn n_inferred(&self) -> u64 {
        self.acc.n_inferred()
    }

    /// Inferences whose backend reported an error so far.
    pub fn n_failed(&self) -> u64 {
        self.n_failed
    }

    /// Stream-feature view of the currently carried detections (what
    /// the policy will see at the next step).
    pub fn stream_features(&self) -> crate::features::FrameFeatures {
        self.features.features(&self.carried)
    }

    /// Online power/energy view of the stream so far — joules, average
    /// watts and GPU-busy fraction metered incrementally per step.
    pub fn power(&self) -> PowerSummary {
        self.meter.summary()
    }

    /// Advance the stream by one frame on a dedicated accelerator.
    ///
    /// Equivalent to one iteration of the legacy `run_realtime` loop:
    /// stepping a fresh session to completion reproduces the monolithic
    /// loop's `RunResult` bit for bit.
    pub fn step(
        &mut self,
        detector: &mut dyn Detector,
        latency: &mut LatencyModel,
    ) -> SessionEvent {
        self.step_shared(detector, latency, 0.0, 1.0)
    }

    /// Advance the stream by one frame on a *shared* accelerator that
    /// becomes free at `resource_free` (stream seconds), with sampled
    /// inference latency multiplied by `inflation` (the multi-stream
    /// contention factor; 1.0 = uncontended).
    ///
    /// With `resource_free <= now` and `inflation == 1.0` this is
    /// exactly [`step`](Self::step).
    pub fn step_shared(
        &mut self,
        detector: &mut dyn Detector,
        latency: &mut LatencyModel,
        resource_free: f64,
        inflation: f64,
    ) -> SessionEvent {
        self.step_with(
            detector,
            &mut |dnn| {
                let base = latency.sample(dnn);
                if inflation == 1.0 {
                    base
                } else {
                    base * inflation
                }
            },
            resource_free,
        )
    }

    /// Advance the stream by one frame with the inference latency
    /// supplied by the caller per selected DNN.
    ///
    /// This is the core step every other form delegates to. Handing the
    /// caller the `DnnKind -> seconds` mapping lets schedulers price a
    /// dispatch by its *context* — e.g. the batched multi-stream
    /// scheduler charges only the marginal per-item cost when the
    /// selected DNN continues the accelerator's current micro-batch
    /// ([`crate::sim::latency::BatchLatencyModel`]). `latency_of` is
    /// called at most once, and only when the frame is actually
    /// inferred.
    pub fn step_with(
        &mut self,
        detector: &mut dyn Detector,
        latency_of: &mut dyn FnMut(DnnKind) -> f64,
        resource_free: f64,
    ) -> SessionEvent {
        if self.is_finished() {
            return SessionEvent::Finished;
        }
        let frame = self.next_frame;
        self.next_frame += 1;
        let gt = self.seq.gt(frame);

        // The frame's capture start doubles as the decision clock for
        // budget governors and as the energy meter's idle horizon.
        let t_capture = self.clock.arrival(frame) - self.clock.period();
        self.meter.advance_to(t_capture);
        self.policy.on_frame(t_capture);
        self.emit(ObsEvent::FramePresented {
            stream: self.obs_stream,
            frame,
            t: t_capture + self.obs_epoch,
        });
        self.span_open(frame, SpanKind::Frame, t_capture);

        // Select from the *previous* frame's detections: the extractor
        // turns the carried set into the stream-feature vector (its
        // `mbbs` channel is bit-identical to the legacy statistic, so
        // Algorithm 1 policies are unaffected by the widening)
        let feats = self.features.features(&self.carried);
        self.mbbs_series.push(feats.mbbs);
        self.span_instant(frame, SpanKind::FeatureExtract, t_capture);
        self.span_open(frame, SpanKind::PredictSelect, t_capture);
        if self.policy.governs() {
            // the governor's feasibility pass runs inside select();
            // any BudgetClamp it emits lands between this instant and
            // the DnnSelected below, all at the same decision time
            self.span_instant(frame, SpanKind::BudgetGovern, t_capture);
        }
        let dnn = self.policy.select(&feats);
        self.emit(ObsEvent::DnnSelected {
            stream: self.obs_stream,
            frame,
            t: t_capture + self.obs_epoch,
            dnn,
        });
        self.span_close(t_capture);

        let (outcome, interval) = self
            .acc
            .on_frame_shared(frame, resource_free, || latency_of(dnn));
        let event = match (outcome, interval) {
            (FrameOutcome::Inferred, Some(interval)) => {
                // the accelerator time is committed whether or not the
                // backend succeeds: the busy interval, energy and
                // deploy accounting describe what the hardware did
                let (s, e) = interval;
                // queueing/contention wait is capture → accelerator
                // start; the inference span carries the busy interval
                self.span_open(frame, SpanKind::DispatchWait, t_capture);
                self.span_close(s);
                self.span_open(frame, SpanKind::Inference, s);
                self.trace.push(s, e, dnn);
                self.meter.on_interval(s, e, dnn);
                self.policy.on_inferred(s, e, dnn);
                self.deploy[dnn.index()] += 1;
                if let Some(prev) = self.last_dnn {
                    if prev != dnn {
                        self.switches += 1;
                    }
                }
                self.last_dnn = Some(dnn);
                self.dnn_series.push(Some(dnn));
                let session_ev = match detector.detect_into(
                    frame,
                    gt,
                    dnn,
                    &mut self.detect_buf,
                ) {
                    Ok(()) => {
                        filter_detections_into(
                            &self.detect_buf,
                            &mut self.carried,
                        );
                        // speed advances only on fresh snapshots: a
                        // carried set matched against itself would read
                        // as zero motion
                        self.features.on_detections(frame, &self.carried);
                        self.emit(ObsEvent::FrameInferred {
                            stream: self.obs_stream,
                            frame,
                            dnn,
                            start: s + self.obs_epoch,
                            end: e + self.obs_epoch,
                        });
                        SessionEvent::Inferred { frame, dnn, interval }
                    }
                    Err(_) => {
                        // failed inference: this frame keeps the stale
                        // carried detections; the stream (and process)
                        // keep running
                        self.n_failed += 1;
                        self.failed_busy_s += e - s;
                        self.emit(ObsEvent::InferenceFailed {
                            stream: self.obs_stream,
                            frame,
                            dnn,
                            start: s + self.obs_epoch,
                            end: e + self.obs_epoch,
                        });
                        SessionEvent::InferenceFailed { frame, dnn, interval }
                    }
                };
                // the inference span ends when the accelerator frees;
                // postprocess (filter + eval bookkeeping) is a
                // zero-width instant; then the frame span closes
                self.span_close(e);
                self.span_instant(frame, SpanKind::Postprocess, e);
                self.span_close(e);
                session_ev
            }
            // `(Inferred, None)` cannot be constructed (the frame
            // clock returns the busy window with every inferred
            // verdict); treating the pairing as a drop keeps the
            // serving path panic-free rather than trusting that
            // invariant with an expect
            (FrameOutcome::Dropped, _) | (FrameOutcome::Inferred, None) => {
                self.dnn_series.push(None);
                // acc.now() is when the blocking inference frees the
                // device — the cause anchor for `tod trace explain-drop`
                self.emit(ObsEvent::FrameDropped {
                    stream: self.obs_stream,
                    frame,
                    t: t_capture + self.obs_epoch,
                    busy_until: self.acc.now() + self.obs_epoch,
                });
                // a dropped frame exits the pipeline at capture: its
                // frame span is zero-width with no stage children
                self.span_close(t_capture);
                SessionEvent::Dropped { frame }
            }
        };
        // evaluate whatever detections the application would see at this
        // frame (fresh or carried) against this frame's ground truth
        self.matcher.match_into(&self.carried, gt, IOU_THRESHOLD, &mut self.eval);
        event
    }

    /// Close the stream and produce the run summary.
    ///
    /// Panics if frames remain unpresented — drive the session to
    /// [`SessionEvent::Finished`] first.
    pub fn finish(mut self) -> RunResult {
        assert!(
            self.is_finished(),
            "finish() called with {} frames unpresented",
            self.frames_remaining()
        );
        // stream runs to the last frame's arrival even if the DNN idles
        self.trace.duration = self
            .trace
            .duration
            .max(self.seq.n_frames() as f64 / self.eval_fps);
        self.meter.advance_to(self.trace.duration);
        // close the stream root span opened by with_recorder
        self.span_close(self.trace.duration);
        self.emit(ObsEvent::StreamLeft {
            stream: self.obs_stream,
            t: self.trace.duration + self.obs_epoch,
            frames: self.seq.n_frames(),
            inferred: self.acc.n_inferred(),
            dropped: self.acc.n_dropped(),
            failed: self.n_failed,
        });
        RunResult {
            policy: self.policy.label(),
            sequence: self.seq.spec.name.clone(),
            fps: self.eval_fps,
            ap: self.eval.ap(ApMethod::AllPoint),
            n_frames: self.seq.n_frames(),
            n_inferred: self.acc.n_inferred(),
            n_dropped: self.acc.n_dropped(),
            n_failed: self.n_failed,
            failed_busy_s: self.failed_busy_s,
            deploy_counts: self.deploy,
            switches: self.switches,
            power: self.meter.summary(),
            trace: self.trace,
            mbbs_series: self.mbbs_series,
            dnn_series: self.dnn_series,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{FixedPolicy, MbbsPolicy};
    use crate::coordinator::scheduler::OracleBackend;
    use crate::dataset::synth::{CameraMotion, SequenceSpec};
    use crate::sim::oracle::OracleDetector;

    fn small_seq(frames: u64) -> Sequence {
        Sequence::generate(SequenceSpec {
            name: "SESS".into(),
            width: 960,
            height: 540,
            fps: 30.0,
            frames,
            density: 6,
            ref_height: 200.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera: CameraMotion::Static,
            seed: 77,
        })
    }

    fn oracle_for(seq: &Sequence) -> OracleBackend {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    }

    #[test]
    fn steps_every_frame_then_finishes() {
        let seq = small_seq(60);
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let mut s =
            StreamSession::new(&seq, FixedPolicy(DnnKind::TinyY288), 30.0);
        let mut frames_seen = 0u64;
        loop {
            match s.step(&mut det, &mut lat) {
                SessionEvent::Finished => break,
                SessionEvent::Inferred { frame, .. }
                | SessionEvent::InferenceFailed { frame, .. }
                | SessionEvent::Dropped { frame } => {
                    frames_seen += 1;
                    assert_eq!(frame, frames_seen);
                }
            }
        }
        assert!(s.is_finished());
        assert_eq!(frames_seen, 60);
        let r = s.finish();
        assert_eq!(r.n_frames, 60);
        assert_eq!(r.n_inferred + r.n_dropped, 60);
    }

    #[test]
    fn finished_session_keeps_returning_finished() {
        let seq = small_seq(5);
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let mut s = StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0);
        while s.step(&mut det, &mut lat) != SessionEvent::Finished {}
        assert_eq!(s.step(&mut det, &mut lat), SessionEvent::Finished);
        assert_eq!(s.frames_remaining(), 0);
        assert!(s.next_infer_frame().is_none());
    }

    #[test]
    #[should_panic(expected = "frames unpresented")]
    fn finish_requires_completion() {
        let seq = small_seq(10);
        let s = StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0);
        let _ = s.finish();
    }

    #[test]
    fn next_infer_frame_skips_doomed_frames() {
        // Y-416 at 30 FPS: after inferring frame 1 (153 ms), frames
        // 2..=4 are already destined to drop
        let seq = small_seq(30);
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let mut s =
            StreamSession::new(&seq, FixedPolicy(DnnKind::Y416), 30.0);
        assert_eq!(s.next_infer_frame(), Some(1));
        let ev = s.step(&mut det, &mut lat);
        assert!(matches!(ev, SessionEvent::Inferred { frame: 1, .. }));
        assert_eq!(s.next_infer_frame(), Some(5));
        let ready = s.next_infer_ready().unwrap();
        assert!((ready - 4.0 / 30.0).abs() < 1e-12);
        let deadline = s.next_infer_deadline().unwrap();
        assert!((deadline - 6.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn shared_floor_delays_start_and_causes_drops() {
        let seq = small_seq(30);
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let mut s =
            StreamSession::new(&seq, FixedPolicy(DnnKind::TinyY288), 30.0);
        // accelerator busy with another stream until t = 0.5 s
        let ev = s.step_shared(&mut det, &mut lat, 0.5, 1.0);
        match ev {
            SessionEvent::Inferred { frame, interval: (start, _), .. } => {
                assert_eq!(frame, 1);
                assert!((start - 0.5).abs() < 1e-12);
            }
            other => panic!("expected inference, got {other:?}"),
        }
        // frames that arrived while the accelerator was foreign-busy drop
        let ev = s.step_shared(&mut det, &mut lat, 0.5, 1.0);
        assert!(matches!(ev, SessionEvent::Dropped { frame: 2 }));
    }

    #[test]
    fn moving_stream_develops_a_speed_estimate() {
        let seq = Sequence::generate(SequenceSpec {
            name: "SPEED".into(),
            width: 960,
            height: 540,
            fps: 30.0,
            frames: 60,
            density: 6,
            ref_height: 220.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera: CameraMotion::Vehicle { flow_speed: 18.0 },
            seed: 77,
        });
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let mut s =
            StreamSession::new(&seq, FixedPolicy(DnnKind::TinyY288), 30.0);
        while s.step(&mut det, &mut lat) != SessionEvent::Finished {}
        let f = s.stream_features();
        // vehicle flow 18 px/frame at mid depth 1.5 over a 1101 px
        // diagonal ≈ 0.011 frame diagonals per frame
        assert!(
            f.speed > 0.004,
            "vehicle stream should read as fast: {f:?}"
        );
        assert!(f.count > 0);

        // a static camera at the same geometry reads much slower
        let static_seq = small_seq(60);
        let mut det2 = oracle_for(&static_seq);
        let mut s2 = StreamSession::new(
            &static_seq,
            FixedPolicy(DnnKind::TinyY288),
            30.0,
        );
        while s2.step(&mut det2, &mut lat) != SessionEvent::Finished {}
        let f2 = s2.stream_features();
        assert!(
            f2.speed < f.speed / 2.0,
            "static {f2:?} vs vehicle {f:?}"
        );
    }

    #[test]
    fn inflation_stretches_busy_interval() {
        let seq = small_seq(10);
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let base = lat.mean(DnnKind::TinyY288);
        let mut s =
            StreamSession::new(&seq, FixedPolicy(DnnKind::TinyY288), 30.0);
        let ev = s.step_shared(&mut det, &mut lat, 0.0, 2.0);
        match ev {
            SessionEvent::Inferred { interval: (start, end), .. } => {
                assert!((end - start - 2.0 * base).abs() < 1e-12);
            }
            other => panic!("expected inference, got {other:?}"),
        }
    }
}
