//! Greedy IoU matching of detections to ground truth within one frame.
//!
//! Detections are visited in descending score order; each claims the
//! unmatched considered-GT box with the highest IoU above the threshold.
//! Unconsidered GT rows (flag 0 / non-person classes after the paper's
//! preprocessing) act as *ignore* regions: detections matching them are
//! removed from scoring entirely rather than counted as false positives,
//! following the MOT devkit.

// Matching sits on the serving path: NaN scores/IoUs must never panic.
#![deny(clippy::unwrap_used)]

use crate::dataset::mot::GtEntry;
use crate::detection::Detection;
use crate::eval::ap::SequenceEval;

/// Standard MOT detection-evaluation IoU threshold.
pub const IOU_THRESHOLD: f64 = 0.5;

/// Outcome of matching one frame.
#[derive(Debug, Clone, Default)]
pub struct FrameMatch {
    /// (score, is_true_positive) per scored detection, unsorted.
    pub scored: Vec<(f32, bool)>,
    /// Number of considered ground-truth boxes in the frame.
    pub n_gt: usize,
    /// Detections discarded for overlapping ignore regions.
    pub n_ignored: usize,
}

/// Match one frame's detections against its ground truth.
///
/// One-shot convenience over [`FrameMatcher`]; per-frame callers on the
/// serving path hold a matcher and use
/// [`FrameMatcher::match_frame_into`] / [`FrameMatcher::match_into`]
/// instead, which reuse every working buffer across frames.
pub fn match_frame(
    dets: &[Detection],
    gt: &[GtEntry],
    iou_threshold: f64,
) -> FrameMatch {
    let mut matcher = FrameMatcher::new();
    let mut out = FrameMatch::default();
    matcher.match_frame_into(dets, gt, iou_threshold, &mut out);
    out
}

/// Greedy frame matching with reusable scratch: the considered/ignore
/// ground-truth partitions, the score order and the taken flags live in
/// the matcher and are re-filled (never re-allocated, once warm) each
/// frame. Pinned bit-identical to the straightforward per-call
/// implementation by `matcher_matches_reference_on_random_frames`.
#[derive(Debug, Default)]
pub struct FrameMatcher {
    /// Indices into `gt` with `is_considered()`, in gt order.
    considered: Vec<usize>,
    /// The complementary ignore-region indices, in gt order.
    ignore: Vec<usize>,
    /// Detection indices in NaN-safe descending score order.
    order: Vec<usize>,
    /// Claim flags, parallel to `considered`.
    gt_taken: Vec<bool>,
}

impl FrameMatcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Match one frame into a caller-owned [`FrameMatch`] (its `scored`
    /// buffer is cleared and refilled, keeping its capacity).
    pub fn match_frame_into(
        &mut self,
        dets: &[Detection],
        gt: &[GtEntry],
        iou_threshold: f64,
        out: &mut FrameMatch,
    ) {
        out.scored.clear();
        let scored = &mut out.scored;
        let (n_gt, n_ignored) = self.run(dets, gt, iou_threshold, |s, tp| {
            scored.push((s, tp));
        });
        out.n_gt = n_gt;
        out.n_ignored = n_ignored;
    }

    /// Match one frame and fold it straight into a [`SequenceEval`] —
    /// the steady-state path of the per-frame serving loop (no
    /// intermediate `FrameMatch`, no allocation once warm).
    ///
    /// Returns the number of ignored detections (informational; the
    /// accumulator does not track them).
    pub fn match_into(
        &mut self,
        dets: &[Detection],
        gt: &[GtEntry],
        iou_threshold: f64,
        eval: &mut SequenceEval,
    ) -> usize {
        let (n_gt, n_ignored) = self.run(dets, gt, iou_threshold, |s, tp| {
            eval.push_scored(s, tp);
        });
        eval.add_gt(n_gt);
        n_ignored
    }

    /// The greedy core: emit `(score, is_tp)` per scored detection in
    /// match order; returns `(n_gt, n_ignored)`.
    fn run(
        &mut self,
        dets: &[Detection],
        gt: &[GtEntry],
        iou_threshold: f64,
        mut emit: impl FnMut(f32, bool),
    ) -> (usize, usize) {
        self.considered.clear();
        self.ignore.clear();
        for (gi, g) in gt.iter().enumerate() {
            if g.is_considered() {
                self.considered.push(gi);
            } else {
                self.ignore.push(gi);
            }
        }

        self.order.clear();
        self.order.extend(0..dets.len());
        // NaN-safe descending score order with NaN ranked last: a
        // NaN-scored detection must neither panic the frame's evaluation
        // nor steal a ground-truth match from a confident detection
        // `sort_unstable_by` never touches the allocator (stable sort
        // buffers above ~20 elements); the index tie-break reproduces
        // the stable order bit for bit on equal scores
        self.order.sort_unstable_by(|&a, &b| {
            crate::detection::by_score_desc_nan_last(
                dets[a].score,
                dets[b].score,
            )
            .then(a.cmp(&b))
        });

        self.gt_taken.clear();
        self.gt_taken.resize(self.considered.len(), false);

        let mut n_ignored = 0usize;
        for oi in 0..self.order.len() {
            let d = &dets[self.order[oi]];
            // best unmatched considered gt
            let mut best: Option<(usize, f64)> = None;
            for ci in 0..self.considered.len() {
                if self.gt_taken[ci] {
                    continue;
                }
                let g = &gt[self.considered[ci]];
                let iou = d.bbox.iou(&g.bbox);
                if iou >= iou_threshold
                    && best.map(|(_, b)| iou > b).unwrap_or(true)
                {
                    best = Some((ci, iou));
                }
            }
            if let Some((ci, _)) = best {
                self.gt_taken[ci] = true;
                emit(d.score, true);
                continue;
            }
            // no considered match: ignore-region overlap removes it from
            // scoring, otherwise it is a false positive
            let ignored = self
                .ignore
                .iter()
                .any(|&gi| d.bbox.iou(&gt[gi].bbox) >= iou_threshold);
            if ignored {
                n_ignored += 1;
            } else {
                emit(d.score, false);
            }
        }
        (self.considered.len(), n_ignored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detection::PERSON_CLASS;
    use crate::geometry::BBox;

    fn gt(x: f64, y: f64, w: f64, h: f64, conf: f64, class: u32) -> GtEntry {
        GtEntry {
            frame: 1,
            id: 1,
            bbox: BBox::new(x, y, w, h),
            conf,
            class: crate::dataset::mot::MotClass::from_id(class),
            visibility: 1.0,
        }
    }

    fn det(x: f64, y: f64, w: f64, h: f64, score: f32) -> Detection {
        Detection::new(BBox::new(x, y, w, h), score, PERSON_CLASS)
    }

    #[test]
    fn perfect_match() {
        let g = vec![gt(0., 0., 10., 10., 1.0, 1)];
        let d = vec![det(0., 0., 10., 10., 0.9)];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        assert_eq!(m.n_gt, 1);
        assert_eq!(m.scored, vec![(0.9, true)]);
    }

    #[test]
    fn miss_is_fp_and_unmatched_gt_counts() {
        let g = vec![gt(0., 0., 10., 10., 1.0, 1)];
        let d = vec![det(100., 100., 10., 10., 0.8)];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        assert_eq!(m.n_gt, 1);
        assert_eq!(m.scored, vec![(0.8, false)]);
    }

    #[test]
    fn one_gt_claims_only_one_detection() {
        let g = vec![gt(0., 0., 10., 10., 1.0, 1)];
        let d = vec![
            det(0., 0., 10., 10., 0.6),
            det(1., 0., 10., 10., 0.9), // higher score claims the gt
        ];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        let tp: Vec<_> = m.scored.iter().filter(|(_, t)| *t).collect();
        let fp: Vec<_> = m.scored.iter().filter(|(_, t)| !*t).collect();
        assert_eq!(tp.len(), 1);
        assert_eq!(tp[0].0, 0.9);
        assert_eq!(fp.len(), 1);
    }

    #[test]
    fn highest_iou_gt_preferred() {
        let g = vec![
            gt(0., 0., 10., 10., 1.0, 1),
            gt(2., 0., 10., 10., 1.0, 1),
        ];
        let d = vec![det(2., 0., 10., 10., 0.9)];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        assert_eq!(m.scored, vec![(0.9, true)]);
        // the overlapping-but-worse gt stays unmatched
        assert_eq!(m.n_gt, 2);
    }

    #[test]
    fn ignore_region_swallows_detection() {
        // a car (class 3, flag zeroed by preprocessing) overlapped by a
        // detection: not a false positive, just removed
        let g = vec![gt(0., 0., 10., 10., 0.0, 3)];
        let d = vec![det(0., 0., 10., 10., 0.9)];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        assert_eq!(m.n_gt, 0);
        assert!(m.scored.is_empty());
        assert_eq!(m.n_ignored, 1);
    }

    #[test]
    fn threshold_is_inclusive() {
        // IoU exactly 0.5: two 10x20 boxes offset so inter/union = 0.5
        // inter = 10*10=100, union = 200+200-100=300 -> 1/3. Make exact:
        // boxes 10x10, overlap 2/3 horizontally: inter 20/3... use simpler:
        // identical boxes -> iou 1.0 >= 0.5 always inclusive; check just
        // below threshold rejects
        let g = vec![gt(0., 0., 10., 10., 1.0, 1)];
        let d = vec![det(5.1, 0., 10., 10., 0.9)]; // iou ≈ 0.324
        let m = match_frame(&d, &g, 0.33);
        assert_eq!(m.scored, vec![(0.9, false)]);
        let m2 = match_frame(&d, &g, 0.32);
        assert_eq!(m2.scored, vec![(0.9, true)]);
    }

    #[test]
    fn empty_inputs() {
        let m = match_frame(&[], &[], IOU_THRESHOLD);
        assert_eq!(m.n_gt, 0);
        assert!(m.scored.is_empty());
    }

    #[test]
    fn nan_score_matches_without_panicking() {
        // one NaN-scored detection among real ones: the frame still
        // matches, with the NaN entry ranked last deterministically
        let g = vec![gt(0., 0., 10., 10., 1.0, 1)];
        let d = vec![
            det(0., 0., 10., 10., 0.6),
            det(100., 100., 10., 10., f32::NAN),
        ];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        assert_eq!(m.n_gt, 1);
        assert_eq!(m.scored.len(), 2);
        let tp = m.scored.iter().filter(|(_, t)| *t).count();
        assert_eq!(tp, 1);
    }

    /// The straightforward per-call implementation `match_frame`
    /// delegated through before the scratch-reusing [`FrameMatcher`]
    /// existed; the oracle for the equivalence property test below.
    fn match_frame_reference(
        dets: &[Detection],
        gt: &[GtEntry],
        iou_threshold: f64,
    ) -> FrameMatch {
        let considered: Vec<&GtEntry> =
            gt.iter().filter(|g| g.is_considered()).collect();
        let ignore: Vec<&GtEntry> =
            gt.iter().filter(|g| !g.is_considered()).collect();

        let mut order: Vec<usize> = (0..dets.len()).collect();
        order.sort_by(|&a, &b| {
            crate::detection::by_score_desc_nan_last(
                dets[a].score,
                dets[b].score,
            )
        });

        let mut gt_taken = vec![false; considered.len()];
        let mut out = FrameMatch {
            scored: Vec::with_capacity(dets.len()),
            n_gt: considered.len(),
            n_ignored: 0,
        };

        for &di in &order {
            let d = &dets[di];
            let mut best: Option<(usize, f64)> = None;
            for (gi, g) in considered.iter().enumerate() {
                if gt_taken[gi] {
                    continue;
                }
                let iou = d.bbox.iou(&g.bbox);
                if iou >= iou_threshold
                    && best.map(|(_, b)| iou > b).unwrap_or(true)
                {
                    best = Some((gi, iou));
                }
            }
            if let Some((gi, _)) = best {
                gt_taken[gi] = true;
                out.scored.push((d.score, true));
                continue;
            }
            let ignored = ignore
                .iter()
                .any(|g| d.bbox.iou(&g.bbox) >= iou_threshold);
            if ignored {
                out.n_ignored += 1;
            } else {
                out.scored.push((d.score, false));
            }
        }
        out
    }

    /// Bitwise (score, tp) equality — NaN scores compare equal to
    /// themselves via `to_bits`, which plain `==` would reject.
    fn scored_eq(a: &[(f32, bool)], b: &[(f32, bool)]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|((sa, ta), (sb, tb))| {
                sa.to_bits() == sb.to_bits() && ta == tb
            })
    }

    #[test]
    fn matcher_matches_reference_on_random_frames() {
        use crate::testing::prop::{Gen, PropConfig};
        // one matcher reused across every case: stale scratch from a
        // previous (larger) frame must not leak into the next
        let mut matcher = FrameMatcher::new();
        let mut out = FrameMatch::default();
        PropConfig::default().run(
            "matcher_matches_reference_on_random_frames",
            |g: &mut Gen| {
                let n_det = g.usize_in(0, 24);
                let n_gt = g.usize_in(0, 16);
                let dets: Vec<Detection> = (0..n_det)
                    .map(|_| {
                        let score = if g.usize_in(0, 9) == 0 {
                            f32::NAN
                        } else {
                            g.f64_in(0.0, 1.0) as f32
                        };
                        det(
                            g.f64_in(-5.0, 40.0),
                            g.f64_in(-5.0, 40.0),
                            g.f64_in(0.0, 25.0),
                            g.f64_in(0.0, 25.0),
                            score,
                        )
                    })
                    .collect();
                let gts: Vec<GtEntry> = (0..n_gt)
                    .map(|_| {
                        // mix considered pedestrians with ignore rows
                        let (conf, class) = if g.bool() {
                            (1.0, 1)
                        } else {
                            (0.0, 3)
                        };
                        gt(
                            g.f64_in(-5.0, 40.0),
                            g.f64_in(-5.0, 40.0),
                            g.f64_in(0.0, 25.0),
                            g.f64_in(0.0, 25.0),
                            conf,
                            class,
                        )
                    })
                    .collect();
                let thr = g.f64_in(0.05, 0.95);

                let reference = match_frame_reference(&dets, &gts, thr);
                matcher.match_frame_into(&dets, &gts, thr, &mut out);
                let frame_ok = scored_eq(&out.scored, &reference.scored)
                    && out.n_gt == reference.n_gt
                    && out.n_ignored == reference.n_ignored;

                let mut eval = SequenceEval::default();
                let n_ignored =
                    matcher.match_into(&dets, &gts, thr, &mut eval);
                let fold_ok = scored_eq(eval.scored(), &reference.scored)
                    && eval.n_gt() == reference.n_gt
                    && n_ignored == reference.n_ignored;

                frame_ok && fold_ok
            },
        );
    }

    #[test]
    fn nan_score_cannot_steal_a_match() {
        // both detections overlap the single gt box; the NaN-scored
        // one ranks last, so the confident detection takes the TP
        let g = vec![gt(0., 0., 10., 10., 1.0, 1)];
        let d = vec![
            det(1., 0., 10., 10., f32::NAN),
            det(0., 0., 10., 10., 0.8),
        ];
        let m = match_frame(&d, &g, IOU_THRESHOLD);
        let tps: Vec<f32> = m
            .scored
            .iter()
            .filter(|(_, t)| *t)
            .map(|(s, _)| *s)
            .collect();
        assert_eq!(tps, vec![0.8], "the finite score must win the gt");
    }
}
