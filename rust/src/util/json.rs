//! Minimal JSON parser + writer (serde_json stand-in; see DESIGN.md §3).
//!
//! Used for the AOT `artifacts/manifest.json` handshake with the Python
//! compile path and for machine-readable experiment results. Supports the
//! full JSON grammar except surrogate-pair unescaping niceties beyond the
//! BMP (the manifest is plain ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — results files diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][i]`-style path access for tests and loaders.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- writer ------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        out.push_str("null"); // JSON has no NaN; match serde_json behaviour
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code).unwrap_or('\u{FFFD}'),
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the full sequence verbatim
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.b.len());
                    self.pos = end;
                    match std::str::from_utf8(&self.b[start..end]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#,
        )
        .unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.at(&["d"]).unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"tod","heads":[{"grid":9,"stride":32}],"ok":true,"x":null,"pi":3.25}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(32.0).to_string(), "32");
        assert_eq!(Json::Num(0.007).to_string(), "0.007");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("a", Json::arr([Json::num(1.0), Json::num(2.0)])),
            ("b", Json::str("x")),
        ]);
        let pretty = v.to_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
