"""Pallas max-pool kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import maxpool2x2
from compile.kernels import ref


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize(
    "n,h,w,c",
    [(1, 2, 2, 1), (1, 8, 8, 3), (2, 16, 12, 7), (1, 36, 36, 32),
     (1, 10, 6, 130)],
)
def test_pool_matches_ref(n, h, w, c):
    x = _rand((n, h, w, c), seed=h * w + c)
    out = maxpool2x2(x)
    np.testing.assert_allclose(out, ref.ref_maxpool2x2(x), rtol=1e-6)


def test_pool_odd_shape_raises():
    with pytest.raises(ValueError):
        maxpool2x2(jnp.zeros((1, 3, 4, 1), jnp.float32))
    with pytest.raises(ValueError):
        maxpool2x2(jnp.zeros((1, 4, 5, 1), jnp.float32))


def test_pool_selects_max_not_mean():
    x = jnp.asarray(
        [[[[1.0], [2.0]], [[3.0], [4.0]]]], jnp.float32
    )  # (1,2,2,1)
    np.testing.assert_allclose(maxpool2x2(x), [[[[4.0]]]])


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 20).map(lambda v: 2 * v),
    w=st.integers(1, 20).map(lambda v: 2 * v),
    c=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_pool_sweep(h, w, c, seed):
    x = _rand((1, h, w, c), seed=seed)
    out = maxpool2x2(x)
    assert out.shape == (1, h // 2, w // 2, c)
    np.testing.assert_allclose(out, ref.ref_maxpool2x2(x), rtol=1e-6)
