"""L2 detector graph: shapes, determinism, pallas/lax path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.mark.parametrize("name", list(model.VARIANTS))
def test_head_shapes(name):
    cfg = model.VARIANTS[name]
    fn = jax.jit(model.detector_fn(cfg, use_pallas=False))
    img = jnp.zeros((1, cfg.input_size, cfg.input_size, 3), jnp.float32)
    heads = fn(img)
    assert len(heads) == len(cfg.head_strides)
    for h, stride in zip(heads, cfg.head_strides):
        g = cfg.input_size // stride
        assert h.shape == (1, g, g, model.HEAD_CHANNELS)


def test_variant_catalog_matches_paper():
    """The four operating points the paper serves, by name."""
    assert set(model.VARIANTS) == {
        "yolov4-tiny-288", "yolov4-tiny-416", "yolov4-288", "yolov4-416",
    }
    # tiny nets have one head at stride 32; full nets add stride 16
    assert model.VARIANTS["yolov4-tiny-416"].head_strides == (32,)
    assert model.VARIANTS["yolov4-416"].head_strides == (32, 16)
    # full nets are strictly larger than tiny nets
    assert (model.param_count(model.VARIANTS["yolov4-416"])
            > model.param_count(model.VARIANTS["yolov4-tiny-416"]))


def test_params_deterministic():
    cfg = model.VARIANTS["yolov4-tiny-288"]
    p1 = model.build_params(cfg)
    p2 = model.build_params(cfg)
    assert sorted(p1) == sorted(p2)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_same_topology_shares_weights_across_sizes():
    """288 and 416 variants of the same topology are the same network at
    a different input resolution (weights identical), like the paper's
    TensorRT engines built from one .weights file."""
    p288 = model.build_params(model.VARIANTS["yolov4-tiny-288"])
    p416 = model.build_params(model.VARIANTS["yolov4-tiny-416"])
    assert sorted(p288) == sorted(p416)
    for k in p288:
        assert p288[k].shape == p416[k].shape


def test_pallas_and_lax_paths_agree():
    cfg = model.VARIANTS["yolov4-tiny-288"]
    img = jnp.asarray(
        np.random.default_rng(0).uniform(size=(1, 288, 288, 3)), jnp.float32
    )
    out_p = jax.jit(model.detector_fn(cfg, use_pallas=True))(img)
    out_l = jax.jit(model.detector_fn(cfg, use_pallas=False))(img)
    for a, b in zip(out_p, out_l):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-3
        )


def test_head_output_is_finite_and_nonconstant():
    cfg = model.VARIANTS["yolov4-288"]
    img = jnp.asarray(
        np.random.default_rng(1).uniform(size=(1, 288, 288, 3)), jnp.float32
    )
    heads = jax.jit(model.detector_fn(cfg, use_pallas=False))(img)
    for h in heads:
        h = np.asarray(h)
        assert np.isfinite(h).all()
        assert h.std() > 1e-6


def test_grid_size_validation():
    cfg = model.VARIANTS["yolov4-416"]
    assert cfg.grid_size(32) == 13
    assert cfg.grid_size(16) == 26
    with pytest.raises(AssertionError):
        cfg.grid_size(30)
