//! ASCII table renderer for the figure/table reproduction harness —
//! `tod figures` prints the same rows/series the paper reports.

/// A simple left/right-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct AsciiTable {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl AsciiTable {
    pub fn new(title: &str, header: Vec<&str>) -> Self {
        AsciiTable {
            title: title.to_string(),
            header: header.into_iter().map(String::from).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "table row width mismatch");
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&render_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row, &widths));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        let _ = ncol;
        out
    }
}

fn render_row(cells: &[String], widths: &[usize]) -> String {
    let mut s = String::from("|");
    for (cell, w) in cells.iter().zip(widths) {
        let pad = w - cell.chars().count();
        // numbers right-aligned, text left-aligned
        let numeric = cell
            .chars()
            .all(|c| c.is_ascii_digit() || ".-+%enaNA".contains(c))
            && !cell.is_empty();
        if numeric {
            s.push(' ');
            s.push_str(&" ".repeat(pad));
            s.push_str(cell);
            s.push(' ');
        } else {
            s.push(' ');
            s.push_str(cell);
            s.push_str(&" ".repeat(pad));
            s.push(' ');
        }
        s.push('|');
    }
    s
}

/// Render a unicode sparkline for a series (telemetry trace figures).
pub fn sparkline(values: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = if (hi - lo).abs() < 1e-12 { 1.0 } else { hi - lo };
    values
        .iter()
        .map(|v| {
            let t = ((v - lo) / span * 7.0).round().clamp(0.0, 7.0) as usize;
            TICKS[t]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_alignment() {
        let mut t = AsciiTable::new("Demo", vec!["name", "ap"]);
        t.push(vec!["tiny-288", "0.42"]);
        t.push(vec!["a-very-long-name", "0.5"]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("| tiny-288"));
        // all lines between separators share a width
        let widths: Vec<usize> =
            s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn row_width_checked() {
        let mut t = AsciiTable::new("", vec!["a", "b"]);
        t.push(vec!["1"]);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
        assert_eq!(sparkline(&[]), "");
        // flat series doesn't divide by zero
        assert_eq!(sparkline(&[2.0, 2.0]).chars().count(), 2);
    }
}
