//! Dataset substrate: MOT challenge file formats and the synthetic
//! pedestrian-world generator that stands in for the MOT17Det videos
//! (see DESIGN.md §3 for the substitution argument).

pub mod catalog;
pub mod ingest;
pub mod mot;
pub mod synth;

pub use catalog::{mot17det_catalog, sequence_spec, SequenceId};
pub use mot::{GtEntry, MotClass};
pub use synth::{CameraMotion, Sequence, SequenceSpec};
