//! Golden-trace conformance: canonical runs, differential margins, and
//! byte-exact golden files.
//!
//! For every matrix scenario the conformance layer replays a fixed set
//! of configurations — the `H_opt` ladder, projected-accuracy
//! selection, the watts-budgeted selector, and the four fixed-DNN
//! baselines — and assembles one [`ScenarioReport`]: all the
//! [`RunRecord`]s plus a [`Differential`] section pinning the claim the
//! matrix exists to defend, *adaptive selection never loses to the best
//! fixed DNN, on any scenario*. Reports render byte-stably, so
//! `tod scenario record` writes goldens under `rust/tests/goldens/` and
//! `tod scenario check` (and CI) re-runs the matrix and compares bytes.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use crate::predictor::{calibrate, CalibrationConfig, CalibrationTable};
use crate::util::json::Json;
use crate::DnnKind;

use super::harness::{run_scenario, HarnessConfig};
use super::matrix::{scenario_spec, ScenarioId};
use super::record::{self, RunRecord};
use super::spec::ScenarioSpec;

/// The `schema` tag identifying a scenario-report document.
pub const SCHEMA_TAG: &str = "tod-scenario-report";

/// Report version this build writes and checks against.
pub const REPORT_VERSION: u32 = 1;

/// Base FPS every conformance scenario must share, so one calibration
/// table (whose drop pricing is per-FPS) serves the whole matrix.
pub const MATRIX_FPS: f64 = 30.0;

/// The calibration table the projected/budgeted configurations select
/// from: the default 5×5 size×speed campaign at [`MATRIX_FPS`],
/// computed once per process (deterministic in its fixed seed).
pub fn calibration_table() -> &'static CalibrationTable {
    static TABLE: OnceLock<CalibrationTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        calibrate(&CalibrationConfig::default_for_fps(MATRIX_FPS))
    })
}

/// The canonical configuration set replayed on every scenario, in
/// report order: ladder TOD, projected, budgeted (projected argmax
/// under the scenario's watts cap), then the four fixed baselines.
pub fn canonical_configs(spec: &ScenarioSpec) -> Vec<HarnessConfig> {
    let table = calibration_table().clone();
    let mut out = vec![
        HarnessConfig::tod(),
        HarnessConfig::projected(table.clone()),
        HarnessConfig::projected(table).with_watts(spec.watts_budget),
    ];
    out.extend(DnnKind::ALL.iter().map(|&k| HarnessConfig::fixed(k)));
    out
}

/// The adaptive-vs-fixed margins the matrix pins per scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Differential {
    /// Config label of the best fixed DNN by mean AP.
    pub best_fixed: String,
    pub best_fixed_ap: f64,
    /// Projected selection's mean AP and its margin over `best_fixed`.
    pub projected_ap: f64,
    pub projected_margin: f64,
    /// Watts cap the budgeted run was governed by.
    pub watts_budget: f64,
    /// Best fixed DNN whose measured board power fits the cap (the
    /// lowest-power fixed config when none fits).
    pub best_feasible_fixed: String,
    pub best_feasible_fixed_ap: f64,
    /// Budgeted selection's mean AP and its margin over
    /// `best_feasible_fixed`.
    pub budgeted_ap: f64,
    pub budgeted_margin: f64,
}

/// One scenario's full conformance artifact: every canonical run plus
/// the differential margins.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    pub scenario: String,
    pub seed: u64,
    pub differential: Differential,
    /// Records in [`canonical_configs`] order.
    pub records: Vec<RunRecord>,
}

impl ScenarioReport {
    /// The golden-file rendering (pretty JSON, sorted keys, trailing
    /// newline). Byte-stable for a fixed report.
    pub fn canonical_text(&self) -> String {
        to_json(self).to_pretty()
    }
}

/// Replay every canonical configuration of `spec` and assemble the
/// report.
pub fn run_report(spec: &ScenarioSpec) -> Result<ScenarioReport, String> {
    if (spec.base_fps - MATRIX_FPS).abs() > 1e-9 {
        return Err(format!(
            "scenario {:?} runs at {} FPS; conformance requires \
             {MATRIX_FPS} FPS (one calibration table serves the matrix)",
            spec.name, spec.base_fps
        ));
    }
    let streams = spec.compile()?;
    let mut records = Vec::new();
    for cfg in canonical_configs(spec) {
        let run = run_scenario(&spec.name, &streams, &cfg)?;
        records.push(RunRecord::from_run(&run, spec.seed));
    }
    let differential = differential(spec, &records)?;
    Ok(ScenarioReport {
        scenario: spec.name.clone(),
        seed: spec.seed,
        differential,
        records,
    })
}

fn differential(
    spec: &ScenarioSpec,
    records: &[RunRecord],
) -> Result<Differential, String> {
    let find = |label: &str| {
        records
            .iter()
            .find(|r| r.config == label)
            .ok_or_else(|| format!("missing canonical run {label:?}"))
    };
    let projected = find("projected")?;
    let budgeted = find(&format!("projected@{}W", spec.watts_budget))?;
    let fixed: Vec<&RunRecord> = records
        .iter()
        .filter(|r| r.config.starts_with("fixed:"))
        .collect();
    if fixed.len() != DnnKind::COUNT {
        return Err(format!(
            "expected {} fixed runs, found {}",
            DnnKind::COUNT,
            fixed.len()
        ));
    }
    let best = fixed
        .iter()
        .max_by(|a, b| a.aggregate.mean_ap.total_cmp(&b.aggregate.mean_ap))
        .expect("fixed set is non-empty");
    let feasible: Vec<&&RunRecord> = fixed
        .iter()
        .filter(|r| r.aggregate.avg_power_w <= spec.watts_budget + 1e-9)
        .collect();
    let best_feasible = if feasible.is_empty() {
        // nothing fits the cap: compare against the coolest fixed run
        fixed
            .iter()
            .min_by(|a, b| {
                a.aggregate.avg_power_w.total_cmp(&b.aggregate.avg_power_w)
            })
            .expect("fixed set is non-empty")
    } else {
        feasible
            .into_iter()
            .max_by(|a, b| {
                a.aggregate.mean_ap.total_cmp(&b.aggregate.mean_ap)
            })
            .expect("feasible set is non-empty")
    };
    Ok(Differential {
        best_fixed: best.config.clone(),
        best_fixed_ap: best.aggregate.mean_ap,
        projected_ap: projected.aggregate.mean_ap,
        projected_margin: projected.aggregate.mean_ap
            - best.aggregate.mean_ap,
        watts_budget: spec.watts_budget,
        best_feasible_fixed: best_feasible.config.clone(),
        best_feasible_fixed_ap: best_feasible.aggregate.mean_ap,
        budgeted_ap: budgeted.aggregate.mean_ap,
        budgeted_margin: budgeted.aggregate.mean_ap
            - best_feasible.aggregate.mean_ap,
    })
}

/// Serialize a report to its versioned JSON document.
pub fn to_json(report: &ScenarioReport) -> Json {
    let d = &report.differential;
    Json::obj(vec![
        ("schema", Json::str(SCHEMA_TAG)),
        ("version", Json::num(REPORT_VERSION as f64)),
        ("scenario", Json::str(&report.scenario)),
        ("seed", Json::num(report.seed as f64)),
        (
            "differential",
            Json::obj(vec![
                ("best_fixed", Json::str(&d.best_fixed)),
                ("best_fixed_ap", Json::num(d.best_fixed_ap)),
                ("projected_ap", Json::num(d.projected_ap)),
                ("projected_margin", Json::num(d.projected_margin)),
                ("watts_budget", Json::num(d.watts_budget)),
                ("best_feasible_fixed", Json::str(&d.best_feasible_fixed)),
                (
                    "best_feasible_fixed_ap",
                    Json::num(d.best_feasible_fixed_ap),
                ),
                ("budgeted_ap", Json::num(d.budgeted_ap)),
                ("budgeted_margin", Json::num(d.budgeted_margin)),
            ]),
        ),
        ("runs", Json::arr(report.records.iter().map(record::to_json))),
    ])
}

/// Golden file path of a scenario under `dir`.
pub fn golden_path(dir: &Path, scenario: &str) -> PathBuf {
    dir.join(format!("{scenario}.json"))
}

/// Re-run the full matrix and write one golden per scenario under
/// `dir`. Returns the written paths.
pub fn write_goldens(dir: &Path) -> Result<Vec<PathBuf>, String> {
    std::fs::create_dir_all(dir)
        .map_err(|e| format!("cannot create {}: {e}", dir.display()))?;
    let mut out = Vec::new();
    for id in ScenarioId::ALL {
        let report = run_report(&scenario_spec(id))?;
        let path = golden_path(dir, &report.scenario);
        std::fs::write(&path, report.canonical_text())
            .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
        out.push(path);
    }
    Ok(out)
}

/// True when no golden file exists yet for any matrix scenario (a
/// fresh checkout before the first `tod scenario record`).
pub fn goldens_missing(dir: &Path) -> bool {
    ScenarioId::ALL
        .iter()
        .all(|id| !golden_path(dir, id.name()).exists())
}

/// Bootstrap: when `dir` holds no goldens at all, record the full
/// matrix into it and return `true`. With any golden present this is a
/// no-op returning `false` — partial sets are *not* repaired silently
/// (a deleted golden must fail the check, not regrow).
pub fn bootstrap_goldens_if_missing(dir: &Path) -> Result<bool, String> {
    if goldens_missing(dir) {
        write_goldens(dir)?;
        return Ok(true);
    }
    Ok(false)
}

/// One scenario's conformance verdict.
#[derive(Debug, Clone, PartialEq)]
pub enum CheckVerdict {
    /// Bytes match the committed golden.
    Match,
    /// The golden file is missing (run `tod scenario record`).
    Missing,
    /// Bytes differ; carries the first differing line (1-based) and a
    /// short excerpt of golden vs observed.
    Mismatch { line: usize, golden: String, observed: String },
}

/// Re-run the matrix and byte-compare each report against the goldens
/// in `dir`. Returns `(scenario name, verdict)` per scenario.
pub fn check_goldens(
    dir: &Path,
) -> Result<Vec<(String, CheckVerdict)>, String> {
    let mut out = Vec::new();
    for id in ScenarioId::ALL {
        let report = run_report(&scenario_spec(id))?;
        let path = golden_path(dir, &report.scenario);
        let verdict = match std::fs::read_to_string(&path) {
            Err(_) => CheckVerdict::Missing,
            Ok(golden) => {
                let observed = report.canonical_text();
                if golden == observed {
                    CheckVerdict::Match
                } else {
                    let (line, g, o) = first_diff(&golden, &observed);
                    CheckVerdict::Mismatch {
                        line,
                        golden: g,
                        observed: o,
                    }
                }
            }
        };
        out.push((report.scenario, verdict));
    }
    Ok(out)
}

/// Event window the failure flight recorder retains per scenario — the
/// tail of the run, with the header's `overwritten` count making any
/// truncation self-describing.
pub const FLIGHT_CAPACITY: usize = 4096;

/// Post-mortem artifacts for one failing scenario: re-run every
/// canonical configuration with the flight recorder and the metrics
/// registry attached to the observability spine, then write
/// `<scenario>.flight.jsonl` (the retained event window) and
/// `<scenario>.metrics.json` (the versioned counters snapshot) under
/// `dir`. Returns the written paths.
pub fn dump_failure_artifacts(
    spec: &ScenarioSpec,
    dir: &Path,
) -> Result<Vec<PathBuf>, String> {
    use std::cell::RefCell;
    use std::rc::Rc;

    use crate::obs::{
        Event, FlightRecorder, MetricsRegistry, Recorder, SharedRecorder,
    };

    use super::harness::run_scenario_observed;

    /// Feed both post-mortem consumers from the one event stream.
    struct Tee {
        flight: FlightRecorder,
        metrics: MetricsRegistry,
    }
    impl Recorder for Tee {
        fn record(&mut self, ev: &Event) {
            self.flight.record(ev);
            self.metrics.record(ev);
        }
    }

    std::fs::create_dir_all(dir)
        .map_err(|e| format!("cannot create {}: {e}", dir.display()))?;
    let streams = spec.compile()?;
    let tee = Rc::new(RefCell::new(Tee {
        flight: FlightRecorder::new(FLIGHT_CAPACITY),
        metrics: MetricsRegistry::new(),
    }));
    let rec: SharedRecorder = tee.clone();
    for cfg in canonical_configs(spec) {
        let run = run_scenario_observed(&spec.name, &streams, &cfg, Some(&rec))?;
        // board-level aggregates are not on the event stream
        let mut t = tee.borrow_mut();
        t.metrics.observe_utilisation(&run.utilisation);
        t.metrics.observe_power(&run.power);
    }
    let t = tee.borrow();
    let flight_path = dir.join(format!("{}.flight.jsonl", spec.name));
    std::fs::write(&flight_path, t.flight.to_jsonl(&spec.name))
        .map_err(|e| format!("cannot write {}: {e}", flight_path.display()))?;
    let metrics_path = dir.join(format!("{}.metrics.json", spec.name));
    std::fs::write(&metrics_path, t.metrics.to_json().to_pretty())
        .map_err(|e| {
            format!("cannot write {}: {e}", metrics_path.display())
        })?;
    Ok(vec![flight_path, metrics_path])
}

/// The SLO spec a scenario is checked against: the default windowed
/// health limits, plus a board-watts cap when the scenario declares a
/// bespoke power budget. The matrix-wide default budget is a campaign
/// parameter, not a per-scenario health promise, so only scenarios
/// that pin their own cap (e.g. `budget-squeeze` at 5.8 W) get the
/// watts signal.
pub fn scenario_slo_spec(spec: &ScenarioSpec) -> crate::obs::SloSpec {
    let slo = crate::obs::SloSpec::default();
    if (spec.watts_budget - crate::app::DEFAULT_WATTS_BUDGET).abs() > 1e-9 {
        slo.with_watts_cap(spec.watts_budget)
    } else {
        slo
    }
}

/// Run the canonical ungoverned TOD ladder over `spec` with an
/// [`crate::obs::EventLog`] attached and return the full event trace
/// (spans included). This is the run `tod slo check` evaluates — and
/// the one a watts-capped scenario exists to indict: the budgeted
/// configurations hold the cap, while the ladder runs hot through the
/// squeeze and must trip the watchdog.
pub fn scenario_slo_events(
    spec: &ScenarioSpec,
) -> Result<Vec<crate::obs::Event>, String> {
    use std::cell::RefCell;
    use std::rc::Rc;

    use crate::obs::{EventLog, SharedRecorder};

    use super::harness::run_scenario_observed;

    let streams = spec.compile()?;
    let log = Rc::new(RefCell::new(EventLog::new()));
    let rec: SharedRecorder = log.clone();
    let cfg = HarnessConfig::tod();
    run_scenario_observed(&spec.name, &streams, &cfg, Some(&rec))?;
    let events = log.borrow().events().to_vec();
    Ok(events)
}

/// Evaluate [`scenario_slo_spec`] over the canonical ladder trace of
/// `spec` — the per-scenario health assertion behind `tod slo check`.
pub fn check_scenario_slo(
    spec: &ScenarioSpec,
) -> Result<crate::obs::SloReport, String> {
    let events = scenario_slo_events(spec)?;
    Ok(crate::obs::slo::check_events(&events, &scenario_slo_spec(spec)))
}

/// First differing line of two texts (1-based), with both lines.
fn first_diff(a: &str, b: &str) -> (usize, String, String) {
    for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
        if la != lb {
            return (i + 1, la.to_string(), lb.to_string());
        }
    }
    let n = a.lines().count().min(b.lines().count());
    (
        n + 1,
        a.lines().nth(n).unwrap_or("<eof>").to_string(),
        b.lines().nth(n).unwrap_or("<eof>").to_string(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Thresholds;
    use crate::predictor::CalibrationTable;
    use crate::scenario::spec::{PhaseSpec, StreamSpec};

    /// A free ladder-shaped table so unit tests never pay for the full
    /// calibration campaign (the real table is exercised by the
    /// integration suite in `rust/tests/scenario.rs`).
    fn ladder_table() -> CalibrationTable {
        CalibrationTable::from_ladder(&Thresholds::h_opt(), &DnnKind::ALL)
    }

    fn tiny_spec() -> ScenarioSpec {
        ScenarioSpec::new(
            "conf-unit",
            "tiny conformance scenario",
            vec![StreamSpec::new(
                "cam0",
                vec![
                    PhaseSpec::new("a", 40).ref_height(140.0),
                    PhaseSpec::new("b", 40).ref_height(430.0),
                ],
            )],
        )
        .seed(77)
    }

    fn tiny_report(spec: &ScenarioSpec) -> ScenarioReport {
        // canonical_configs but with the free ladder table
        let streams = spec.compile().unwrap();
        let mut configs = vec![
            HarnessConfig::tod(),
            HarnessConfig::projected(ladder_table()),
            HarnessConfig::projected(ladder_table())
                .with_watts(spec.watts_budget),
        ];
        configs.extend(DnnKind::ALL.iter().map(|&k| HarnessConfig::fixed(k)));
        let records = configs
            .iter()
            .map(|cfg| {
                RunRecord::from_run(
                    &run_scenario(&spec.name, &streams, cfg).unwrap(),
                    spec.seed,
                )
            })
            .collect::<Vec<_>>();
        let differential = differential(spec, &records).unwrap();
        ScenarioReport {
            scenario: spec.name.clone(),
            seed: spec.seed,
            differential,
            records,
        }
    }

    #[test]
    fn report_text_is_stable_and_parses() {
        let spec = tiny_spec();
        let a = tiny_report(&spec);
        let b = tiny_report(&spec);
        assert_eq!(a.canonical_text(), b.canonical_text());
        let doc = Json::parse(&a.canonical_text()).unwrap();
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(SCHEMA_TAG)
        );
        assert_eq!(
            doc.get("runs").and_then(Json::as_arr).map(<[Json]>::len),
            Some(3 + DnnKind::COUNT)
        );
    }

    #[test]
    fn differential_names_real_configs() {
        let spec = tiny_spec();
        let r = tiny_report(&spec);
        let d = &r.differential;
        assert!(d.best_fixed.starts_with("fixed:"), "{d:?}");
        assert!(d.best_feasible_fixed.starts_with("fixed:"), "{d:?}");
        assert_eq!(
            d.projected_margin,
            d.projected_ap - d.best_fixed_ap
        );
        assert_eq!(
            d.budgeted_margin,
            d.budgeted_ap - d.best_feasible_fixed_ap
        );
        assert_eq!(d.watts_budget, spec.watts_budget);
    }

    #[test]
    fn golden_write_and_check_cycle_on_temp_dir() {
        // exercise the file plumbing with a hand-rolled single report
        // (the full-matrix cycle runs in the integration suite)
        let spec = tiny_spec();
        let report = tiny_report(&spec);
        let dir = std::env::temp_dir().join("tod_conf_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = golden_path(&dir, &report.scenario);
        std::fs::write(&path, report.canonical_text()).unwrap();
        let golden = std::fs::read_to_string(&path).unwrap();
        assert_eq!(golden, report.canonical_text());
        // a perturbed byte must be caught as a mismatch
        let tampered = golden.replace("\"seed\": 77", "\"seed\": 78");
        assert_ne!(tampered, golden);
        let (line, g, o) = first_diff(&golden, &tampered);
        assert!(line >= 1);
        assert_ne!(g, o);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn slo_watchdog_flags_budget_squeeze_and_passes_steady_sparse() {
        // golden SLO semantics: the ungoverned ladder runs the heavy
        // nets straight through budget-squeeze's 5.8 W cap...
        let squeeze = scenario_spec(ScenarioId::BudgetSqueeze);
        let spec = scenario_slo_spec(&squeeze);
        assert_eq!(spec.watts_cap, Some(squeeze.watts_budget));
        let r = check_scenario_slo(&squeeze).unwrap();
        assert!(r.breached(), "expected a breach, got {:?}", r.events);
        assert!(
            r.breaches_of(crate::obs::SloSignal::Watts) >= 1,
            "expected a watts breach, got {:?}",
            r.events
        );
        // ...while the near-control scenario stays healthy throughout
        let sparse = scenario_spec(ScenarioId::SteadySparse);
        assert_eq!(scenario_slo_spec(&sparse).watts_cap, None);
        let r = check_scenario_slo(&sparse).unwrap();
        assert!(!r.breached(), "unexpected breaches: {:?}", r.events);
        assert!(r.checks > 0);
    }

    #[test]
    fn scenario_slo_report_is_deterministic() {
        let spec = scenario_spec(ScenarioId::BudgetSqueeze);
        let a = check_scenario_slo(&spec).unwrap();
        let b = check_scenario_slo(&spec).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn non_matrix_fps_is_rejected() {
        let spec = tiny_spec().base_fps(14.0);
        assert!(run_report(&spec).unwrap_err().contains("14"));
    }
}
