//! Contention-aware scheduling of N streams over one shared accelerator.
//!
//! The paper evaluates one camera per Jetson board; production edge
//! deployments (ROMA, and the parallel-detection work in PAPERS.md)
//! multiplex many cameras onto one accelerator. This module interleaves
//! N [`StreamSession`]s in virtual time:
//!
//! * the accelerator runs **one inference at a time** — per-stream busy
//!   intervals never overlap on the shared device;
//! * each inference's latency is inflated by the
//!   [`ContentionModel`] according to how many streams were waiting at
//!   dispatch time (engine swaps / bandwidth sharing);
//! * frames that arrive while the accelerator serves *any* stream are
//!   dropped with the same Algorithm 2 carry-forward accounting the
//!   single-stream loop uses — multi-stream pressure shows up as higher
//!   per-stream drop rates and staler carried boxes, exactly the
//!   mechanism behind the paper's Fig. 7.
//!
//! Two dispatch orders are provided: round-robin (fair, oblivious) and
//! earliest-deadline-first (dispatch the stream whose pending frame is
//! superseded soonest). A 1-stream scheduler reduces to the legacy
//! `run_realtime` exactly: no waiting peers means no inflation and no
//! foreign busy time, so every step is bit-identical.

use crate::power::{EnergyMeter, PowerSummary};
use crate::sim::latency::{ContentionModel, LatencyModel};
use crate::telemetry::utilisation::UtilisationSummary;

use super::scheduler::{Detector, RunResult};
use super::session::{SessionEvent, StreamSession};

/// Order in which waiting streams get the shared accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DispatchPolicy {
    /// Cycle stream indices, skipping streams with nothing to infer.
    RoundRobin,
    /// Dispatch the stream whose next inferable frame is superseded
    /// (goes stale) earliest.
    EarliestDeadlineFirst,
}

impl DispatchPolicy {
    pub fn label(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::EarliestDeadlineFirst => "edf",
        }
    }
}

impl std::fmt::Display for DispatchPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for DispatchPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => {
                Ok(DispatchPolicy::RoundRobin)
            }
            "edf" | "earliest-deadline-first" => {
                Ok(DispatchPolicy::EarliestDeadlineFirst)
            }
            other => Err(format!(
                "unknown dispatch policy: {other} (want rr|edf)"
            )),
        }
    }
}

/// Everything an N-stream run produces.
#[derive(Debug, Clone)]
pub struct MultiStreamResult {
    /// Per-stream run summaries, in `add_stream` order. Each carries its
    /// own `ScheduleTrace` of (non-overlapping) busy intervals.
    pub per_stream: Vec<RunResult>,
    /// Dispatch order the run used.
    pub dispatch: DispatchPolicy,
    /// Aggregate accelerator utilisation over the merged timeline.
    pub utilisation: UtilisationSummary,
    /// Board-level energy/power summary over the merged timeline
    /// (what a shared [`crate::power::PowerBudget`] governs).
    pub power: PowerSummary,
}

impl MultiStreamResult {
    /// Mean AP across streams.
    pub fn mean_ap(&self) -> f64 {
        if self.per_stream.is_empty() {
            return 0.0;
        }
        self.per_stream.iter().map(|r| r.ap).sum::<f64>()
            / self.per_stream.len() as f64
    }

    /// Aggregate drop rate (dropped frames over all frames).
    pub fn drop_rate(&self) -> f64 {
        let frames: u64 = self.per_stream.iter().map(|r| r.n_frames).sum();
        let dropped: u64 = self.per_stream.iter().map(|r| r.n_dropped).sum();
        if frames == 0 {
            0.0
        } else {
            dropped as f64 / frames as f64
        }
    }
}

/// One stream slot: a session plus the detector backend computing its
/// frames' detections. (Detection *math* is per-stream — the oracle is
/// seeded per sequence — while detection *time* is shared through the
/// scheduler's single virtual accelerator.)
struct StreamSlot<'a> {
    session: StreamSession<'a>,
    detector: Box<dyn Detector + 'a>,
}

/// Interleaves N [`StreamSession`]s over one shared virtual accelerator.
pub struct MultiStreamScheduler<'a> {
    streams: Vec<StreamSlot<'a>>,
    latency: LatencyModel,
    contention: ContentionModel,
    dispatch: DispatchPolicy,
}

impl<'a> MultiStreamScheduler<'a> {
    pub fn new(
        dispatch: DispatchPolicy,
        contention: ContentionModel,
        latency: LatencyModel,
    ) -> Self {
        MultiStreamScheduler {
            streams: Vec::new(),
            latency,
            contention,
            dispatch,
        }
    }

    /// Register a stream (its session plus detector backend).
    pub fn add_stream(
        &mut self,
        session: StreamSession<'a>,
        detector: Box<dyn Detector + 'a>,
    ) {
        self.streams.push(StreamSlot { session, detector });
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Run every stream to completion; returns per-stream results plus
    /// the aggregate utilisation summary.
    pub fn run(self) -> MultiStreamResult {
        let MultiStreamScheduler {
            mut streams,
            mut latency,
            contention,
            dispatch,
        } = self;
        let mut gpu_free = 0.0f64;
        let mut rr_cursor = 0usize;

        loop {
            // streams that still have a frame the accelerator will run
            let candidates: Vec<(usize, f64, f64)> = streams
                .iter()
                .enumerate()
                .filter_map(|(i, s)| {
                    let ready = s.session.next_infer_ready()?;
                    let deadline = s.session.next_infer_deadline()?;
                    Some((i, ready, deadline))
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            let chosen = match dispatch {
                DispatchPolicy::RoundRobin => candidates
                    .iter()
                    .find(|(i, _, _)| *i >= rr_cursor)
                    .or_else(|| candidates.first())
                    .copied()
                    .unwrap(),
                DispatchPolicy::EarliestDeadlineFirst => candidates
                    .iter()
                    .copied()
                    .min_by(|a, b| {
                        (a.2, a.0).partial_cmp(&(b.2, b.0)).unwrap()
                    })
                    .unwrap(),
            };
            let (idx, ready, _) = chosen;
            // contention: streams whose pending frame is waiting when
            // this inference starts (the dispatched one included)
            let start_est = gpu_free.max(ready);
            let occupancy = candidates
                .iter()
                .filter(|(_, r, _)| *r <= start_est + 1e-12)
                .count()
                .max(1);
            let inflation = contention.factor(occupancy);

            // drain the stream's doomed frames, then run its inference
            let slot = &mut streams[idx];
            loop {
                match slot.session.step_shared(
                    slot.detector.as_mut(),
                    &mut latency,
                    gpu_free,
                    inflation,
                ) {
                    SessionEvent::Inferred { interval: (_, end), .. } => {
                        gpu_free = gpu_free.max(end);
                        break;
                    }
                    SessionEvent::Dropped { .. } => continue,
                    SessionEvent::Finished => break,
                }
            }
            rr_cursor = (idx + 1) % streams.len();
        }

        // drain streams whose remaining frames are all destined to drop
        for slot in &mut streams {
            while !slot.session.is_finished() {
                slot.session.step_shared(
                    slot.detector.as_mut(),
                    &mut latency,
                    gpu_free,
                    1.0,
                );
            }
        }

        let per_stream: Vec<RunResult> = streams
            .into_iter()
            .map(|s| s.session.finish())
            .collect();
        let traces: Vec<&crate::telemetry::tegrastats::ScheduleTrace> =
            per_stream.iter().map(|r| &r.trace).collect();
        let utilisation = UtilisationSummary::from_traces(&traces);
        let power = EnergyMeter::from_trace(&utilisation.merged).summary();
        MultiStreamResult { per_stream, dispatch, utilisation, power }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::MbbsPolicy;
    use crate::coordinator::scheduler::{run_realtime, OracleBackend};
    use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
    use crate::sim::oracle::OracleDetector;

    fn seq(seed: u64, frames: u64) -> Sequence {
        Sequence::generate(SequenceSpec {
            name: format!("MS-{seed}"),
            width: 960,
            height: 540,
            fps: 30.0,
            frames,
            density: 6,
            ref_height: 220.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera: CameraMotion::Static,
            seed,
        })
    }

    fn oracle(s: &Sequence) -> OracleBackend {
        OracleBackend(OracleDetector::new(
            s.spec.seed,
            s.spec.width as f64,
            s.spec.height as f64,
        ))
    }

    fn run_n(
        seqs: &[Sequence],
        dispatch: DispatchPolicy,
        contention: ContentionModel,
    ) -> MultiStreamResult {
        let mut sched = MultiStreamScheduler::new(
            dispatch,
            contention,
            LatencyModel::deterministic(),
        );
        for s in seqs {
            sched.add_stream(
                StreamSession::new(s, MbbsPolicy::tod_default(), 30.0),
                Box::new(oracle(s)),
            );
        }
        sched.run()
    }

    #[test]
    fn dispatch_policy_parses() {
        assert_eq!(
            "rr".parse::<DispatchPolicy>().unwrap(),
            DispatchPolicy::RoundRobin
        );
        assert_eq!(
            "EDF".parse::<DispatchPolicy>().unwrap(),
            DispatchPolicy::EarliestDeadlineFirst
        );
        assert!("lifo".parse::<DispatchPolicy>().is_err());
        assert_eq!(DispatchPolicy::RoundRobin.to_string(), "round-robin");
    }

    #[test]
    fn one_stream_matches_legacy_run_realtime() {
        let s = seq(11, 150);
        let mut det = oracle(&s);
        let mut pol = MbbsPolicy::tod_default();
        let mut lat = LatencyModel::deterministic();
        let legacy = run_realtime(&s, &mut pol, &mut det, &mut lat, 30.0);
        let multi = run_n(
            &[s.clone()],
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        let r = &multi.per_stream[0];
        assert_eq!(r.ap, legacy.ap);
        assert_eq!(r.deploy_counts, legacy.deploy_counts);
        assert_eq!(r.n_dropped, legacy.n_dropped);
        assert_eq!(r.switches, legacy.switches);
        assert_eq!(r.mbbs_series, legacy.mbbs_series);
        assert_eq!(r.dnn_series, legacy.dnn_series);
        assert_eq!(r.trace.busy, legacy.trace.busy);
        assert_eq!(r.trace.duration, legacy.trace.duration);
    }

    #[test]
    fn shared_accelerator_never_double_booked() {
        for dispatch in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::EarliestDeadlineFirst,
        ] {
            let seqs: Vec<Sequence> =
                (0..4).map(|i| seq(100 + i, 90)).collect();
            let r = run_n(&seqs, dispatch, ContentionModel::jetson_nano());
            assert_eq!(r.per_stream.len(), 4);
            assert!(
                r.utilisation.overlap_seconds() < 1e-9,
                "overlap under {dispatch}"
            );
            for s in &r.per_stream {
                assert_eq!(s.n_inferred + s.n_dropped, s.n_frames);
            }
        }
    }

    #[test]
    fn contention_raises_drop_rate() {
        let one = run_n(
            &[seq(7, 120)],
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        let seqs: Vec<Sequence> = (0..6).map(|i| seq(7 + i, 120)).collect();
        let six = run_n(
            &seqs,
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        assert!(
            six.drop_rate() > one.drop_rate(),
            "6-stream drop {} vs 1-stream {}",
            six.drop_rate(),
            one.drop_rate()
        );
        // an oversubscribed accelerator should be busy almost always
        assert!(
            six.utilisation.utilisation() > 0.8,
            "util {}",
            six.utilisation.utilisation()
        );
    }

    #[test]
    fn zero_streams_is_benign() {
        let sched = MultiStreamScheduler::new(
            DispatchPolicy::RoundRobin,
            ContentionModel::none(),
            LatencyModel::deterministic(),
        );
        let r = sched.run();
        assert!(r.per_stream.is_empty());
        assert_eq!(r.mean_ap(), 0.0);
        assert_eq!(r.drop_rate(), 0.0);
    }
}
