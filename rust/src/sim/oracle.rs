//! Oracle detector: the trained-COCO-weights stand-in.
//!
//! Given a frame's ground truth and a DNN profile, the oracle emits
//! detections whose statistics follow the profile's capacity model:
//! size-dependent recall, visibility-attenuated detectability,
//! capacity-dependent localisation noise and confidence, plus a false-
//! positive process. Detections for (sequence, frame, dnn) are a pure
//! function of the seed — the schedule taken by a policy cannot perturb
//! what a DNN "would have seen" on a frame, which keeps policy
//! comparisons paired and noise-free.

use crate::dataset::mot::GtEntry;
use crate::detection::{Detection, PERSON_CLASS};
use crate::geometry::BBox;
use crate::sim::profiles::DnnProfile;
use crate::util::rng::Rng;
use crate::DnnKind;

/// Visibility exponent: heavily occluded objects are harder for every
/// detector (p *= visibility^GAMMA).
const VIS_GAMMA: f64 = 1.4;

/// A deterministic detector simulator for one sequence.
#[derive(Debug, Clone)]
pub struct OracleDetector {
    seed: u64,
    frame_w: f64,
    frame_h: f64,
    profiles: [DnnProfile; 4],
}

impl OracleDetector {
    pub fn new(seed: u64, frame_w: f64, frame_h: f64) -> Self {
        OracleDetector {
            seed,
            frame_w,
            frame_h,
            profiles: [
                DnnProfile::of(DnnKind::TinyY288),
                DnnProfile::of(DnnKind::TinyY416),
                DnnProfile::of(DnnKind::Y288),
                DnnProfile::of(DnnKind::Y416),
            ],
        }
    }

    pub fn profile(&self, dnn: DnnKind) -> &DnnProfile {
        &self.profiles[dnn.index()]
    }

    /// Simulate running `dnn` on the frame with the given ground truth.
    /// Deterministic in (seed, frame, dnn).
    pub fn detect(
        &self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> Vec<Detection> {
        let mut out = Vec::with_capacity(gt.len() + 2);
        self.detect_into(frame, gt, dnn, &mut out);
        out
    }

    /// [`detect`](Self::detect) into a caller-owned buffer (cleared
    /// first) — the zero-alloc steady-state form used by the serving
    /// loop. Identical RNG stream, identical detections.
    pub fn detect_into(
        &self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
        out: &mut Vec<Detection>,
    ) {
        out.clear();
        let p = self.profile(dnn);
        // Independent stream per (frame, dnn): mix both into the seed.
        let mut rng = Rng::new(
            self.seed
                ^ frame.wrapping_mul(0x9e3779b97f4a7c15)
                ^ ((dnn.index() as u64 + 1) << 56),
        );
        for g in gt {
            // The detector sees persons only (the paper filters classes).
            if !g.class.is_person() {
                continue;
            }
            let area = g.bbox.area_frac(self.frame_w, self.frame_h);
            let vis = if g.visibility < 0.0 { 1.0 } else { g.visibility };
            let p_det = p.detect_prob(area) * vis.powf(VIS_GAMMA);
            if !rng.chance(p_det) {
                continue;
            }
            // localisation noise scales with box size and inverse capacity
            let nx = rng.normal(0.0, p.loc_noise * g.bbox.w);
            let ny = rng.normal(0.0, p.loc_noise * g.bbox.h);
            let sw = (1.0 + rng.normal(0.0, p.loc_noise)).clamp(0.6, 1.6);
            let sh = (1.0 + rng.normal(0.0, p.loc_noise)).clamp(0.6, 1.6);
            let (cx, cy) = g.bbox.center();
            let bbox = BBox::from_center(
                cx + nx,
                cy + ny,
                g.bbox.w * sw,
                g.bbox.h * sh,
            )
            .clip(self.frame_w, self.frame_h);
            if bbox.is_degenerate() {
                continue;
            }
            // confidence: capacity base + detectability margin + noise
            let score = (p.score_mean
                + 0.25 * (p_det - 0.5)
                + rng.normal(0.0, 0.10))
            .clamp(0.05, 0.999) as f32;
            out.push(Detection::new(bbox, score, PERSON_CLASS));
        }
        // false positives: Poisson count, random geometry, low-ish scores
        let n_fp = rng.poisson(p.fp_rate);
        for _ in 0..n_fp {
            let h = rng.uniform(0.03, 0.25) * self.frame_h;
            let w = h * rng.uniform(0.3, 0.6);
            let x = rng.uniform(0.0, (self.frame_w - w).max(1.0));
            let y = rng.uniform(0.0, (self.frame_h - h).max(1.0));
            let score =
                (0.30 + rng.normal(0.0, 0.07)).clamp(0.05, 0.70) as f32;
            out.push(Detection::new(
                BBox::new(x, y, w, h),
                score,
                PERSON_CLASS,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::mot::MotClass;

    fn gt_box(x: f64, y: f64, w: f64, h: f64, vis: f64) -> GtEntry {
        GtEntry {
            frame: 1,
            id: 1,
            bbox: BBox::new(x, y, w, h),
            conf: 1.0,
            class: MotClass::Pedestrian,
            visibility: vis,
        }
    }

    fn large_gt(n: usize) -> Vec<GtEntry> {
        (0..n)
            .map(|i| {
                let mut g =
                    gt_box(50.0 + 60.0 * i as f64, 100.0, 160.0, 380.0, 1.0);
                g.id = i as i64 + 1;
                g
            })
            .collect()
    }

    fn small_gt(n: usize) -> Vec<GtEntry> {
        (0..n)
            .map(|i| {
                let mut g =
                    gt_box(50.0 + 40.0 * i as f64, 100.0, 18.0, 42.0, 1.0);
                g.id = i as i64 + 1;
                g
            })
            .collect()
    }

    #[test]
    fn deterministic_per_frame_and_dnn() {
        let o = OracleDetector::new(1, 1920.0, 1080.0);
        let gt = large_gt(5);
        let a = o.detect(10, &gt, DnnKind::Y416);
        let b = o.detect(10, &gt, DnnKind::Y416);
        assert_eq!(a, b);
        let c = o.detect(11, &gt, DnnKind::Y416);
        let d = o.detect(10, &gt, DnnKind::Y288);
        assert!(a != c || a != d); // different streams
    }

    #[test]
    fn detect_into_matches_detect_with_stale_buffer() {
        let o = OracleDetector::new(1, 1920.0, 1080.0);
        let gt = large_gt(5);
        let mut buf = vec![
            Detection::new(BBox::new(0.0, 0.0, 1.0, 1.0), 0.5, 99);
            32
        ];
        for f in 0..50u64 {
            for dnn in [DnnKind::TinyY288, DnnKind::Y416] {
                o.detect_into(f, &gt, dnn, &mut buf);
                assert_eq!(buf, o.detect(f, &gt, dnn));
            }
        }
    }

    #[test]
    fn recall_gap_small_objects() {
        // heavyweight recall >> lightweight recall on small objects
        let o = OracleDetector::new(2, 1920.0, 1080.0);
        let gt = small_gt(10);
        let count = |dnn: DnnKind| -> usize {
            (0..300).map(|f| {
                o.detect(f, &gt, dnn)
                    .iter()
                    .filter(|d| d.score > 0.35)
                    .count()
            })
            .sum()
        };
        let tiny = count(DnnKind::TinyY288);
        let heavy = count(DnnKind::Y416);
        assert!(
            heavy as f64 > tiny as f64 * 1.5,
            "heavy {heavy} vs tiny {tiny}"
        );
    }

    #[test]
    fn recall_parity_large_objects() {
        let o = OracleDetector::new(3, 1920.0, 1080.0);
        let gt = large_gt(10);
        let count = |dnn: DnnKind| -> usize {
            (0..300).map(|f| o.detect(f, &gt, dnn).len()).sum()
        };
        let tiny = count(DnnKind::TinyY288) as f64;
        let heavy = count(DnnKind::Y416) as f64;
        assert!(
            (heavy / tiny) < 1.25,
            "large objects should equalise: heavy {heavy} tiny {tiny}"
        );
    }

    #[test]
    fn occlusion_reduces_recall() {
        let o = OracleDetector::new(4, 1920.0, 1080.0);
        let visible = large_gt(8);
        let occluded: Vec<GtEntry> = visible
            .iter()
            .cloned()
            .map(|mut g| {
                g.visibility = 0.15;
                g
            })
            .collect();
        let count = |gt: &[GtEntry]| -> usize {
            (0..200).map(|f| o.detect(f, gt, DnnKind::Y416).len()).sum()
        };
        assert!(count(&occluded) * 2 < count(&visible));
    }

    #[test]
    fn localisation_noise_ordering() {
        // tiny-288 boxes are sloppier than Y-416 boxes (mean IoU to gt)
        let o = OracleDetector::new(5, 1920.0, 1080.0);
        let gt = large_gt(6);
        let mean_iou = |dnn: DnnKind| -> f64 {
            let mut total = 0.0;
            let mut n = 0usize;
            for f in 0..200 {
                for d in o.detect(f, &gt, dnn) {
                    if d.score < 0.35 {
                        continue;
                    }
                    let best = gt
                        .iter()
                        .map(|g| g.bbox.iou(&d.bbox))
                        .fold(0.0f64, f64::max);
                    if best > 0.1 {
                        total += best;
                        n += 1;
                    }
                }
            }
            total / n as f64
        };
        let tiny = mean_iou(DnnKind::TinyY288);
        let heavy = mean_iou(DnnKind::Y416);
        assert!(heavy > tiny + 0.02, "heavy {heavy} vs tiny {tiny}");
    }

    #[test]
    fn non_person_gt_never_detected_as_tp_source() {
        let o = OracleDetector::new(6, 1920.0, 1080.0);
        let mut g = gt_box(100.0, 100.0, 300.0, 300.0, 1.0);
        g.class = MotClass::Car;
        // only false positives may appear
        let dets = o.detect(1, &[g], DnnKind::Y416);
        for d in &dets {
            // FP geometry is random; none should precisely track the car
            assert!(d.bbox.iou(&BBox::new(100.0, 100.0, 300.0, 300.0)) < 0.5);
        }
    }

    #[test]
    fn detections_stay_in_frame() {
        let o = OracleDetector::new(7, 640.0, 480.0);
        let gt = vec![gt_box(600.0, 440.0, 80.0, 80.0, 1.0)];
        for f in 0..100 {
            for d in o.detect(f, &gt, DnnKind::TinyY288) {
                assert!(d.bbox.x >= 0.0 && d.bbox.y >= 0.0);
                assert!(d.bbox.right() <= 640.0 + 1e-9);
                assert!(d.bbox.bottom() <= 480.0 + 1e-9);
            }
        }
    }
}
