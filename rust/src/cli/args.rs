//! Positional/flag argument parsing: `cmd [subcommand] --flag value
//! --switch positional...`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` pairs. A flag followed by another flag (or nothing)
    /// is stored with an empty value (boolean switch).
    pub flags: BTreeMap<String, String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => String::new(),
                };
                out.flags.insert(name.to_string(), value);
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Get a flag's value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Boolean switch: present (with or without a value)?
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Typed flag with a default.
    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, String> {
        match self.get(name) {
            None | Some("") => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value for --{name}: {v:?}")),
        }
    }

    /// Typed comma-separated list flag with a default (e.g.
    /// `--scale 1,2,4,8`). Empty segments are rejected.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, String>
    where
        T: Clone,
    {
        match self.get(name) {
            None | Some("") => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|tok| {
                    tok.trim().parse().map_err(|_| {
                        format!(
                            "invalid value for --{name}: {tok:?} \
                             (in {v:?})"
                        )
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("figures --id fig8 --out results");
        assert_eq!(a.command.as_deref(), Some("figures"));
        assert_eq!(a.get("id"), Some("fig8"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn boolean_switches() {
        let a = parse("figures --all --verbose --id fig4");
        assert!(a.has("all"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("all"), Some(""));
        assert_eq!(a.get("id"), Some("fig4"));
    }

    #[test]
    fn positionals_after_command() {
        let a = parse("run MOT17-04 MOT17-11 --fps 30");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["MOT17-04", "MOT17-11"]);
        assert_eq!(a.get("fps"), Some("30"));
    }

    #[test]
    fn typed_parse_and_default() {
        let a = parse("x --fps 14.5");
        assert_eq!(a.get_parse("fps", 30.0).unwrap(), 14.5);
        assert_eq!(a.get_parse("missing", 7u32).unwrap(), 7);
        assert!(a.get_parse::<f64>("fps", 0.0).is_ok());
        let bad = parse("x --fps abc");
        assert!(bad.get_parse::<f64>("fps", 0.0).is_err());
    }

    #[test]
    fn list_parse_and_default() {
        let a = parse("multistream --scale 1,2,4");
        assert_eq!(a.get_list("scale", &[8usize]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_list("missing", &[8usize]).unwrap(), vec![8]);
        let spaced = parse("x --scale 1,2");
        assert_eq!(spaced.get_list("scale", &[0u32]).unwrap(), vec![1, 2]);
        let bad = parse("x --scale 1,zap");
        assert!(bad.get_list("scale", &[0u32]).is_err());
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.command.is_none());
        assert!(a.flags.is_empty());
    }
}
