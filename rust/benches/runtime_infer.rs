//! Bench: real CPU-PJRT inference latency per variant (the measured
//! counterpart of Fig. 5) plus rasterization and decode. Skips cleanly
//! when artifacts are absent.

use std::path::PathBuf;

use tod::bench::{black_box, Bench};
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::runtime::decode::decode;
use tod::runtime::pool::EnginePool;
use tod::runtime::raster::rasterize;
use tod::DnnKind;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime_infer: artifacts not built; skipping (run `make artifacts`)");
        return;
    }
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let seq = Sequence::generate(SequenceSpec {
        name: "BENCH".into(),
        width: 640,
        height: 480,
        fps: 30.0,
        frames: 4,
        density: 6,
        ref_height: 220.0,
        depth_range: (1.0, 2.2),
        walk_speed: 1.5,
        camera: CameraMotion::Static,
        seed: 7,
    });
    let gt = seq.gt(1);

    let mut b = Bench::slow();
    for k in DnnKind::ALL {
        let engine = pool.engine(k).unwrap();
        let size = engine.spec().input_size;
        let img = rasterize(gt, 640.0, 480.0, size, 1);
        b.case(&format!("raster/{}", k.artifact_name()), || {
            black_box(rasterize(black_box(gt), 640.0, 480.0, size, 1));
        });
        b.case(&format!("pjrt_infer/{}", k.artifact_name()), || {
            black_box(engine.infer(black_box(&img)).unwrap());
        });
        let heads = engine.infer(&img).unwrap();
        let spec = engine.spec().clone();
        b.case(&format!("decode/{}", k.artifact_name()), || {
            black_box(decode(black_box(&heads), &spec, 640.0, 480.0));
        });
    }
    b.save_csv("runtime_infer.csv").ok();
}
