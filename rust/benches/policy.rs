//! Bench: the per-frame scheduling overhead the paper claims is
//! "negligible" — Algorithm 1 selection and the MBBS median.
//!
//! Target (EXPERIMENTS.md §Perf): both well under a microsecond, i.e.
//! 4-5 orders of magnitude below the 27-153 ms inference latencies.

use tod::bench::{black_box, Bench};
use tod::coordinator::policy::MbbsPolicy;
use tod::detection::{mbbs, nms, Detection, PERSON_CLASS};
use tod::geometry::BBox;
use tod::util::rng::Rng;

fn synth_dets(n: usize, seed: u64) -> Vec<Detection> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            Detection::new(
                BBox::new(
                    rng.uniform(0.0, 1800.0),
                    rng.uniform(0.0, 1000.0),
                    rng.uniform(10.0, 300.0),
                    rng.uniform(20.0, 500.0),
                ),
                rng.uniform(0.05, 1.0) as f32,
                PERSON_CLASS,
            )
        })
        .collect()
}

fn main() {
    let mut b = Bench::new();
    let policy = MbbsPolicy::tod_default();

    b.case("policy/select_pure_x1000", || {
        // 1000 selections per iteration: divide the reported time by
        // 1000 for the per-frame cost (~3 ns)
        for i in 0..1000u32 {
            black_box(policy.select_pure(black_box(i as f64 * 1e-4)));
        }
    });

    for n in [5usize, 20, 45] {
        let dets = synth_dets(n, n as u64);
        b.case(&format!("mbbs/n={n}"), || {
            black_box(mbbs(black_box(&dets), 1920.0, 1080.0));
        });
    }

    for n in [20usize, 45, 100] {
        let dets = synth_dets(n, n as u64);
        b.case(&format!("nms/n={n}"), || {
            black_box(nms(black_box(&dets), 0.45));
        });
    }

    // the full per-frame coordinator step (select + mbbs), amortized
    let dets = synth_dets(30, 7);
    b.case("coordinator/full_frame_decision", || {
        let m = mbbs(black_box(&dets), 1920.0, 1080.0);
        black_box(policy.select_pure(m));
    });

    b.save_csv("policy.csv").ok();
}
