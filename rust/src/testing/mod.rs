//! Property-testing mini-harness (proptest stand-in; DESIGN.md §3) and
//! the shared integration-test fixtures.

pub mod fixtures;
pub mod prop;

pub use prop::{Gen, PropConfig};
