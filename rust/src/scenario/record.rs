//! The canonical, byte-stable record of one scenario run.
//!
//! A [`RunRecord`] is the golden-file unit: everything a configuration
//! run produced — per-stream AP, deploy counts, drops, switches, power,
//! and per-phase series — flattened into plain numbers. Serialisation
//! is versioned (schema tag + version) and *byte-stable*: object keys
//! are sorted ([`crate::util::json::Json`] stores objects in a
//! `BTreeMap`), floats print in Rust's shortest round-trippable form,
//! and no wall-clock or platform value ever enters the document. The
//! same seed therefore reproduces the same bytes, which is what makes
//! `tod scenario check` diffs meaningful (pinned by the golden-
//! stability test in `rust/tests/scenario.rs`).

use crate::util::json::Json;
use crate::DnnKind;

use super::harness::{ScenarioRun, StreamRun};

/// The `schema` tag identifying a run-record document.
pub const SCHEMA_TAG: &str = "tod-scenario-run";

/// Run-record version this build reads and writes.
pub const RECORD_VERSION: u32 = 1;

/// Per-phase slice of one stream's run.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRecord {
    pub label: String,
    pub frames: u64,
    pub inferred: u64,
    pub dropped: u64,
    /// Inference count per DNN within the phase.
    pub deploy: [u64; DnnKind::COUNT],
    /// Mean of the per-frame MBBS the policy saw during the phase.
    pub mean_mbbs: f64,
}

/// One stream's flattened outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamRecord {
    pub label: String,
    pub join_s: f64,
    pub eval_fps: f64,
    pub policy: String,
    pub ap: f64,
    pub frames: u64,
    pub inferred: u64,
    pub dropped: u64,
    pub failed: u64,
    pub switches: u64,
    pub deploy: [u64; DnnKind::COUNT],
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub gpu_busy_frac: f64,
    pub phases: Vec<PhaseRecord>,
}

/// Scenario-level aggregate of a run.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregateRecord {
    pub mean_ap: f64,
    pub frames: u64,
    pub inferred: u64,
    pub dropped: u64,
    pub failed: u64,
    pub switches: u64,
    /// Board-time makespan, seconds.
    pub makespan_s: f64,
    /// Board busy fraction over the makespan.
    pub utilisation: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub gpu_busy_frac: f64,
}

/// The canonical record of one (scenario × configuration) run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub scenario: String,
    pub config: String,
    pub seed: u64,
    pub aggregate: AggregateRecord,
    pub streams: Vec<StreamRecord>,
}

impl RunRecord {
    /// Flatten a harness run into its canonical record.
    pub fn from_run(run: &ScenarioRun, seed: u64) -> RunRecord {
        let streams: Vec<StreamRecord> =
            run.per_stream.iter().map(stream_record).collect();
        let sum = |f: fn(&StreamRecord) -> u64| -> u64 {
            streams.iter().map(f).sum()
        };
        RunRecord {
            scenario: run.scenario.clone(),
            config: run.config.clone(),
            seed,
            aggregate: AggregateRecord {
                mean_ap: run.mean_ap(),
                frames: sum(|s| s.frames),
                inferred: sum(|s| s.inferred),
                dropped: sum(|s| s.dropped),
                failed: sum(|s| s.failed),
                switches: sum(|s| s.switches),
                makespan_s: run.utilisation.makespan,
                utilisation: run.utilisation.utilisation(),
                energy_j: run.power.energy_j,
                avg_power_w: run.power.avg_power_w,
                gpu_busy_frac: run.power.gpu_busy_frac,
            },
            streams,
        }
    }

    /// The golden-file rendering: pretty JSON with sorted keys and a
    /// trailing newline. Byte-stable for a fixed record.
    pub fn canonical_text(&self) -> String {
        to_json(self).to_pretty()
    }
}

fn stream_record(s: &StreamRun) -> StreamRecord {
    let r = &s.result;
    let mut phases = Vec::with_capacity(s.phase_starts.len());
    for (pi, &start) in s.phase_starts.iter().enumerate() {
        let frames = s.phase_frames[pi];
        // 0-based frame index range of the phase in the per-frame series
        let lo = (start - 1) as usize;
        let hi = (lo + frames as usize).min(r.dnn_series.len());
        let mut deploy = [0u64; DnnKind::COUNT];
        let mut inferred = 0u64;
        for d in r.dnn_series[lo..hi].iter().flatten() {
            deploy[d.index()] += 1;
            inferred += 1;
        }
        let span = (hi - lo).max(1) as f64;
        let mean_mbbs =
            r.mbbs_series[lo..hi].iter().sum::<f64>() / span;
        phases.push(PhaseRecord {
            label: s.phase_labels[pi].clone(),
            frames,
            inferred,
            dropped: (hi - lo) as u64 - inferred,
            deploy,
            mean_mbbs,
        });
    }
    StreamRecord {
        label: s.label.clone(),
        join_s: s.join_s,
        eval_fps: r.fps,
        policy: r.policy.clone(),
        ap: r.ap,
        frames: r.n_frames,
        inferred: r.n_inferred,
        dropped: r.n_dropped,
        failed: r.n_failed,
        switches: r.switches,
        deploy: r.deploy_counts,
        energy_j: r.power.energy_j,
        avg_power_w: r.power.avg_power_w,
        gpu_busy_frac: r.power.gpu_busy_frac,
        phases,
    }
}

fn deploy_json(deploy: &[u64; DnnKind::COUNT]) -> Json {
    Json::arr(deploy.iter().map(|&v| Json::num(v as f64)))
}

fn deploy_from_json(v: &Json) -> Result<[u64; DnnKind::COUNT], String> {
    let arr = v.as_arr().ok_or("deploy is not an array")?;
    if arr.len() != DnnKind::COUNT {
        return Err(format!(
            "deploy has {} entries (want {})",
            arr.len(),
            DnnKind::COUNT
        ));
    }
    let mut out = [0u64; DnnKind::COUNT];
    for (i, cell) in arr.iter().enumerate() {
        out[i] = cell
            .as_usize()
            .ok_or("deploy cell is not a non-negative integer")?
            as u64;
    }
    Ok(out)
}

/// Serialize a record to its versioned JSON document.
pub fn to_json(record: &RunRecord) -> Json {
    let streams = record.streams.iter().map(|s| {
        let phases = s.phases.iter().map(|p| {
            Json::obj(vec![
                ("label", Json::str(&p.label)),
                ("frames", Json::num(p.frames as f64)),
                ("inferred", Json::num(p.inferred as f64)),
                ("dropped", Json::num(p.dropped as f64)),
                ("deploy", deploy_json(&p.deploy)),
                ("mean_mbbs", Json::num(p.mean_mbbs)),
            ])
        });
        Json::obj(vec![
            ("label", Json::str(&s.label)),
            ("join_s", Json::num(s.join_s)),
            ("eval_fps", Json::num(s.eval_fps)),
            ("policy", Json::str(&s.policy)),
            ("ap", Json::num(s.ap)),
            ("frames", Json::num(s.frames as f64)),
            ("inferred", Json::num(s.inferred as f64)),
            ("dropped", Json::num(s.dropped as f64)),
            ("failed", Json::num(s.failed as f64)),
            ("switches", Json::num(s.switches as f64)),
            ("deploy", deploy_json(&s.deploy)),
            ("energy_j", Json::num(s.energy_j)),
            ("avg_power_w", Json::num(s.avg_power_w)),
            ("gpu_busy_frac", Json::num(s.gpu_busy_frac)),
            ("phases", Json::arr(phases)),
        ])
    });
    let a = &record.aggregate;
    Json::obj(vec![
        ("schema", Json::str(SCHEMA_TAG)),
        ("version", Json::num(RECORD_VERSION as f64)),
        ("scenario", Json::str(&record.scenario)),
        ("config", Json::str(&record.config)),
        ("seed", Json::num(record.seed as f64)),
        (
            "aggregate",
            Json::obj(vec![
                ("mean_ap", Json::num(a.mean_ap)),
                ("frames", Json::num(a.frames as f64)),
                ("inferred", Json::num(a.inferred as f64)),
                ("dropped", Json::num(a.dropped as f64)),
                ("failed", Json::num(a.failed as f64)),
                ("switches", Json::num(a.switches as f64)),
                ("makespan_s", Json::num(a.makespan_s)),
                ("utilisation", Json::num(a.utilisation)),
                ("energy_j", Json::num(a.energy_j)),
                ("avg_power_w", Json::num(a.avg_power_w)),
                ("gpu_busy_frac", Json::num(a.gpu_busy_frac)),
            ]),
        ),
        ("streams", Json::arr(streams)),
    ])
}

/// Parse and validate a record from its JSON document.
pub fn from_json(doc: &Json) -> Result<RunRecord, String> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("missing 'schema' tag")?;
    if schema != SCHEMA_TAG {
        return Err(format!("wrong schema: {schema:?} (want {SCHEMA_TAG:?})"));
    }
    let version = doc
        .get("version")
        .and_then(Json::as_usize)
        .ok_or("missing 'version'")?;
    if version != RECORD_VERSION as usize {
        return Err(format!(
            "run record version {version} unsupported (this build reads \
             version {RECORD_VERSION}; re-run `tod scenario record`)"
        ));
    }
    let str_field = |v: &Json, key: &str| {
        v.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing '{key}'"))
    };
    let num = |v: &Json, key: &str| {
        v.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing '{key}'"))
    };
    let count = |v: &Json, key: &str| {
        v.get(key)
            .and_then(Json::as_usize)
            .map(|n| n as u64)
            .ok_or_else(|| format!("missing '{key}'"))
    };
    let a = doc.get("aggregate").ok_or("missing 'aggregate'")?;
    let aggregate = AggregateRecord {
        mean_ap: num(a, "mean_ap")?,
        frames: count(a, "frames")?,
        inferred: count(a, "inferred")?,
        dropped: count(a, "dropped")?,
        failed: count(a, "failed")?,
        switches: count(a, "switches")?,
        makespan_s: num(a, "makespan_s")?,
        utilisation: num(a, "utilisation")?,
        energy_j: num(a, "energy_j")?,
        avg_power_w: num(a, "avg_power_w")?,
        gpu_busy_frac: num(a, "gpu_busy_frac")?,
    };
    let mut streams = Vec::new();
    for s in doc
        .get("streams")
        .and_then(Json::as_arr)
        .ok_or("missing 'streams'")?
    {
        let mut phases = Vec::new();
        for p in s
            .get("phases")
            .and_then(Json::as_arr)
            .ok_or("stream: missing 'phases'")?
        {
            phases.push(PhaseRecord {
                label: str_field(p, "label")?,
                frames: count(p, "frames")?,
                inferred: count(p, "inferred")?,
                dropped: count(p, "dropped")?,
                deploy: deploy_from_json(
                    p.get("deploy").ok_or("phase: missing 'deploy'")?,
                )?,
                mean_mbbs: num(p, "mean_mbbs")?,
            });
        }
        streams.push(StreamRecord {
            label: str_field(s, "label")?,
            join_s: num(s, "join_s")?,
            eval_fps: num(s, "eval_fps")?,
            policy: str_field(s, "policy")?,
            ap: num(s, "ap")?,
            frames: count(s, "frames")?,
            inferred: count(s, "inferred")?,
            dropped: count(s, "dropped")?,
            failed: count(s, "failed")?,
            switches: count(s, "switches")?,
            deploy: deploy_from_json(
                s.get("deploy").ok_or("stream: missing 'deploy'")?,
            )?,
            energy_j: num(s, "energy_j")?,
            avg_power_w: num(s, "avg_power_w")?,
            gpu_busy_frac: num(s, "gpu_busy_frac")?,
            phases,
        });
    }
    Ok(RunRecord {
        scenario: str_field(doc, "scenario")?,
        config: str_field(doc, "config")?,
        seed: doc
            .get("seed")
            .and_then(Json::as_usize)
            .ok_or("missing 'seed'")? as u64,
        aggregate,
        streams,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::harness::{run_scenario, HarnessConfig};
    use crate::scenario::spec::{PhaseSpec, ScenarioSpec, StreamSpec};

    fn sample_record() -> RunRecord {
        let spec = ScenarioSpec::new(
            "record-unit",
            "two-phase record scenario",
            vec![StreamSpec::new(
                "cam0",
                vec![
                    PhaseSpec::new("a", 40).ref_height(130.0),
                    PhaseSpec::new("b", 40).ref_height(420.0),
                ],
            )],
        )
        .seed(3);
        let streams = spec.compile().unwrap();
        let run =
            run_scenario(&spec.name, &streams, &HarnessConfig::tod()).unwrap();
        RunRecord::from_run(&run, spec.seed)
    }

    #[test]
    fn record_accounting_is_consistent() {
        let r = sample_record();
        assert_eq!(r.streams.len(), 1);
        let s = &r.streams[0];
        assert_eq!(s.frames, 80);
        assert_eq!(s.inferred + s.dropped, s.frames);
        assert_eq!(s.deploy.iter().sum::<u64>(), s.inferred);
        // per-phase slices partition the stream
        assert_eq!(s.phases.len(), 2);
        let ph_frames: u64 = s.phases.iter().map(|p| p.frames).sum();
        let ph_inferred: u64 = s.phases.iter().map(|p| p.inferred).sum();
        let ph_dropped: u64 = s.phases.iter().map(|p| p.dropped).sum();
        assert_eq!(ph_frames, s.frames);
        assert_eq!(ph_inferred, s.inferred);
        assert_eq!(ph_dropped, s.dropped);
        for p in &s.phases {
            assert_eq!(p.deploy.iter().sum::<u64>(), p.inferred);
        }
        // phase b's close-up crowd must read much larger than phase a
        assert!(s.phases[1].mean_mbbs > s.phases[0].mean_mbbs * 3.0);
        assert_eq!(r.aggregate.frames, s.frames);
        assert_eq!(r.aggregate.mean_ap, s.ap);
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let r = sample_record();
        let doc = to_json(&r);
        assert_eq!(from_json(&doc).unwrap(), r);
        let reparsed = Json::parse(&r.canonical_text()).unwrap();
        assert_eq!(from_json(&reparsed).unwrap(), r);
    }

    #[test]
    fn canonical_text_is_byte_stable_through_a_round_trip() {
        // the golden contract: parse(text) -> to_json -> text must be
        // the identity, or `tod scenario check` diffs are meaningless
        let r = sample_record();
        let text = r.canonical_text();
        let back = from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.canonical_text(), text);
        assert!(text.ends_with('\n'));
    }

    #[test]
    fn wrong_schema_and_version_rejected() {
        let doc = to_json(&sample_record());
        let mut wrong_schema = doc.clone();
        if let Json::Obj(m) = &mut wrong_schema {
            m.insert("schema".into(), Json::str("nope"));
        }
        assert!(from_json(&wrong_schema).unwrap_err().contains("schema"));
        let mut wrong_version = doc;
        if let Json::Obj(m) = &mut wrong_version {
            m.insert("version".into(), Json::num(9.0));
        }
        assert!(from_json(&wrong_version).unwrap_err().contains("version 9"));
    }
}
