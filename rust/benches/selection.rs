//! Bench: per-frame cost of feature extraction + DNN selection, for the
//! MBBS threshold ladder vs the projected-accuracy policy.
//!
//! This pins the paper's "negligible computational overhead" claim for
//! the widened selection path: the full per-frame decision (extract the
//! stream features from the carried detections, then select) must stay
//! under 50 µs — 3+ orders of magnitude below the 27–153 ms inference
//! latencies. The `*_frame_decision` cases are the per-frame numbers to
//! read; `extractor/on_detections` is the extra cost paid only on
//! inferred frames (snapshot matching + EWMA update).

use tod::bench::{black_box, Bench};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::projected::ProjectedAccuracyPolicy;
use tod::detection::{Detection, PERSON_CLASS};
use tod::features::{FeatureExtractor, FrameFeatures};
use tod::geometry::BBox;
use tod::predictor::{calibrate, CalibrationConfig};
use tod::sim::latency::LatencyModel;
use tod::util::rng::Rng;

fn synth_dets(n: usize, seed: u64) -> Vec<Detection> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            Detection::new(
                BBox::new(
                    rng.uniform(0.0, 1800.0),
                    rng.uniform(0.0, 1000.0),
                    rng.uniform(10.0, 120.0),
                    rng.uniform(20.0, 280.0),
                ),
                rng.uniform(0.4, 1.0) as f32,
                PERSON_CLASS,
            )
        })
        .collect()
}

/// Shift a detection set by (dx, dy) — the "next frame" snapshot.
fn shifted(dets: &[Detection], dx: f64, dy: f64) -> Vec<Detection> {
    dets.iter()
        .map(|d| Detection::new(d.bbox.shifted(dx, dy), d.score, d.class_id))
        .collect()
}

fn main() {
    let mut b = Bench::new();
    let mbbs_policy = MbbsPolicy::tod_default();
    let table = calibrate(&CalibrationConfig::quick(30.0));
    let projected = ProjectedAccuracyPolicy::new(
        table,
        &LatencyModel::deterministic(),
    );

    // per-frame decision: features from the carried set, then select.
    // MOT17 densities run 7..42; bench the mid and the max.
    for n in [10usize, 42] {
        let dets = synth_dets(n, n as u64);
        let fx = FeatureExtractor::new(1920.0, 1080.0);

        b.case(&format!("mbbs/frame_decision/n={n}"), || {
            let f = fx.features(black_box(&dets));
            black_box(mbbs_policy.select_pure(f.mbbs));
        });

        b.case(&format!("projected/frame_decision/n={n}"), || {
            let f = fx.features(black_box(&dets));
            black_box(projected.select_pure(&f));
        });
    }

    // the snapshot-matching update paid once per *inferred* frame:
    // O(|prev| * |cur|) greedy IoU/centroid matching + EWMA
    for n in [10usize, 42] {
        let a = synth_dets(n, n as u64);
        let bset = shifted(&a, 6.0, 1.0);
        let mut fx = FeatureExtractor::new(1920.0, 1080.0);
        let mut frame = 0u64;
        b.case(&format!("extractor/on_detections/n={n}"), || {
            frame += 1;
            let snap = if frame % 2 == 0 { &a } else { &bset };
            fx.on_detections(frame, black_box(snap));
        });
    }

    // selection alone (table lookup vs threshold compare)
    b.case("projected/select_only", || {
        let f = FrameFeatures {
            mbbs: 0.012,
            count: 20,
            density: 0.2,
            speed: 0.008,
        };
        black_box(projected.select_pure(black_box(&f)));
    });
    b.case("mbbs/select_only", || {
        black_box(mbbs_policy.select_pure(black_box(0.012)));
    });

    b.save_csv("selection.csv").ok();
}
