//! End-to-end checks of the observability layer (ISSUE 7 acceptance).
//!
//! Covers the four contract points the unit tests inside `obs/` cannot
//! reach on their own:
//!
//! 1. two runs under the same seed produce **byte-identical** JSONL
//!    traces (determinism is a property of the whole emit path, not
//!    just the serializer);
//! 2. `explain_drops` reconstructs a non-`Unknown` cause for **every**
//!    dropped frame of a real budget-clamped run;
//! 3. attaching a `NullRecorder` leaves the per-step event stream and
//!    allocation profile of `StreamSession::step` unchanged;
//! 4. a `MetricsRegistry` driven purely by the event stream agrees
//!    with the `RunResult` the scheduler computes independently.

use std::cell::RefCell;
use std::rc::Rc;

use tod::app::DEFAULT_WATTS_BUDGET;
use tod::coordinator::{
    run_realtime_observed, FixedPolicy, MbbsPolicy, OracleBackend,
    RunResult, SessionEvent, StreamSession,
};
use tod::dataset::catalog::{generate, SequenceId};
use tod::obs::replay::{explain_drops, parse_trace, DropCause};
use tod::obs::{
    shared, Event, JsonlSink, MetricsRegistry, NullRecorder, SharedRecorder,
};
use tod::perf::count_allocs;
use tod::power::{BudgetConfig, BudgetedPolicy, PowerBudget};
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn oracle_backend(seq: &tod::dataset::Sequence) -> OracleBackend {
    OracleBackend(OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    ))
}

/// A fixed-Y416 run under the default 6.5 W cap: the heavy variant is
/// documented infeasible at saturation (`app::campaign`), so the
/// governor must clamp — giving the trace both `budget_clamp` events
/// and capacity drops to explain.
fn budgeted_y416_trace() -> (String, RunResult) {
    let id = SequenceId::Mot05;
    let seq = generate(id);
    let mut det = oracle_backend(&seq);
    let mut lat = LatencyModel::deterministic();
    let budget = PowerBudget::try_new(
        BudgetConfig {
            watts_cap: Some(DEFAULT_WATTS_BUDGET),
            gpu_cap_pct: None,
            window_s: 1.0,
            rate_cap: None,
        },
        &lat,
    )
    .expect("default watts cap is a valid budget");

    let sink = Rc::new(RefCell::new(JsonlSink::new("obs-integration")));
    let rec: SharedRecorder = sink.clone();
    let mut policy =
        BudgetedPolicy::masking(Box::new(FixedPolicy(DnnKind::Y416)), budget)
            .with_recorder(rec.clone(), 0);
    let r = run_realtime_observed(
        &seq,
        &mut policy,
        &mut det,
        &mut lat,
        id.eval_fps(),
        Some((rec.clone(), 0)),
    );
    let text = sink.borrow().contents().to_string();
    (text, r)
}

#[test]
fn same_seed_traces_are_byte_identical() {
    let (a, ra) = budgeted_y416_trace();
    let (b, rb) = budgeted_y416_trace();
    assert_eq!(ra.n_inferred, rb.n_inferred);
    assert_eq!(a, b, "same-seed traces differ");
    assert!(
        a.lines().count() > 10,
        "trace suspiciously short: {} lines",
        a.lines().count()
    );
    assert!(
        a.contains("\"frame_inferred\""),
        "trace carries no inference events"
    );
}

#[test]
fn budgeted_trace_explains_every_drop() {
    let (text, r) = budgeted_y416_trace();
    let (header, events) = parse_trace(&text).expect("trace parses");
    assert!(header.is_some(), "sink writes a schema header line");

    let clamps = events
        .iter()
        .filter(|e| matches!(e, Event::BudgetClamp { .. }))
        .count();
    assert!(
        clamps > 0,
        "6.5 W cap on a saturated Y416 run must clamp at least once"
    );

    let dropped = events
        .iter()
        .filter(|e| matches!(e, Event::FrameDropped { .. }))
        .count();
    assert_eq!(dropped as u64, r.n_dropped, "trace misses dropped frames");
    assert!(dropped > 0, "expected capacity drops in a saturated run");

    let explained = explain_drops(&events);
    assert_eq!(explained.len(), dropped);
    for ex in &explained {
        assert!(
            ex.cause != DropCause::Unknown,
            "frame {} drop has no reconstructed cause",
            ex.frame
        );
        assert!(
            ex.blocking.is_some(),
            "frame {} drop lacks its blocking inference",
            ex.frame
        );
    }
}

#[test]
fn null_recorder_keeps_steps_alloc_identical() {
    let seq = generate(SequenceId::Mot02);
    let n = seq.n_frames() as usize;

    let mut det_a = oracle_backend(&seq);
    let mut det_b = oracle_backend(&seq);
    let mut lat_a = LatencyModel::deterministic();
    let mut lat_b = LatencyModel::deterministic();
    let mut plain =
        StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0);
    let mut observed =
        StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0)
            .with_recorder(shared(NullRecorder), 0, 0.0);

    for i in 0..n {
        let (da, ea) = count_allocs(|| plain.step(&mut det_a, &mut lat_a));
        let (db, eb) =
            count_allocs(|| observed.step(&mut det_b, &mut lat_b));
        assert!(!matches!(ea, SessionEvent::Finished));
        assert_eq!(ea, eb, "recorder changed behaviour at step {i}");
        // transient growth steps are allowed to allocate, but they must
        // allocate the *same* amount — the null recorder is invisible
        if i >= n / 4 {
            assert_eq!(
                da.allocs, db.allocs,
                "null recorder changed alloc count at step {i}"
            );
        }
    }
}

#[test]
fn metrics_registry_matches_run_counts() {
    let seq = generate(SequenceId::Mot02);
    let mut det = oracle_backend(&seq);
    let mut lat = LatencyModel::deterministic();
    let registry = Rc::new(RefCell::new(MetricsRegistry::new()));
    let rec: SharedRecorder = registry.clone();
    let mut policy = MbbsPolicy::tod_default();
    let r = run_realtime_observed(
        &seq,
        &mut policy,
        &mut det,
        &mut lat,
        30.0,
        Some((rec.clone(), 0)),
    );

    let reg = registry.borrow();
    assert_eq!(reg.frames_presented, r.n_frames);
    assert_eq!(reg.frames_inferred, r.n_inferred);
    assert_eq!(reg.frames_dropped, r.n_dropped);
    assert_eq!(reg.frames_failed, r.n_failed);
    assert_eq!(reg.deploy, r.deploy_counts);
    assert_eq!(reg.streams_joined, 1);
    assert_eq!(reg.streams_left, 1);
    assert_eq!(reg.infer_latency_s.count(), r.n_inferred + r.n_failed);

    let prom = reg.to_prometheus();
    assert!(
        prom.contains(&format!("tod_frames_inferred_total {}", r.n_inferred)),
        "prometheus exposition disagrees with the run"
    );

    // snapshot round-trip reproduces the exposition byte-for-byte
    let back = MetricsRegistry::from_json(&reg.to_json())
        .expect("snapshot round-trips");
    assert_eq!(back.to_prometheus(), prom);
}
