//! Versioned JSON persistence for [`ScenarioSpec`].
//!
//! Scenarios are *data*, not code: a deployment can describe its own
//! edge workloads (phases, churn, noise) in a JSON document and replay
//! them through the same harness that pins the built-in matrix. The
//! schema carries an explicit tag + version (the
//! [`crate::predictor::store`] discipline) so a binary never silently
//! misreads a scenario written by a different generation.
//!
//! ```json
//! {
//!   "schema": "tod-scenario",
//!   "version": 1,
//!   "name": "rush-hour-surge",
//!   "description": "...",
//!   "seed": 23056, "width": 960, "height": 540,
//!   "base_fps": 30, "watts_budget": 6.5,
//!   "streams": [
//!     { "label": "cam0", "join_s": 0,
//!       "phases": [
//!         { "label": "calm", "frames": 150, "density": 6,
//!           "ref_height": 320, "depth_near": 1.0, "depth_far": 2.2,
//!           "walk_speed": 1.5, "fps_scale": 1,
//!           "camera": {"kind": "static"},
//!           "noise": {"miss": 0, "conf_loss": 0} } ] } ]
//! }
//! ```

use std::path::Path;

use crate::dataset::synth::CameraMotion;
use crate::util::json::Json;

use super::spec::{NoiseProfile, PhaseSpec, ScenarioSpec, StreamSpec};

/// The `schema` tag identifying a scenario document.
pub const SCHEMA_TAG: &str = "tod-scenario";

/// Scenario document version this build reads and writes.
pub const SCENARIO_VERSION: u32 = 1;

fn camera_to_json(camera: &CameraMotion) -> Json {
    match camera {
        CameraMotion::Static => Json::obj(vec![("kind", Json::str("static"))]),
        CameraMotion::Walking { pan_speed } => Json::obj(vec![
            ("kind", Json::str("walking")),
            ("pan_speed", Json::num(*pan_speed)),
        ]),
        CameraMotion::Vehicle { flow_speed } => Json::obj(vec![
            ("kind", Json::str("vehicle")),
            ("flow_speed", Json::num(*flow_speed)),
        ]),
    }
}

fn camera_from_json(v: &Json) -> Result<CameraMotion, String> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("camera: missing 'kind'")?;
    match kind {
        "static" => Ok(CameraMotion::Static),
        "walking" => Ok(CameraMotion::Walking {
            pan_speed: v
                .get("pan_speed")
                .and_then(Json::as_f64)
                .ok_or("camera walking: missing 'pan_speed'")?,
        }),
        "vehicle" => Ok(CameraMotion::Vehicle {
            flow_speed: v
                .get("flow_speed")
                .and_then(Json::as_f64)
                .ok_or("camera vehicle: missing 'flow_speed'")?,
        }),
        other => Err(format!("camera: unknown kind {other:?}")),
    }
}

fn phase_to_json(p: &PhaseSpec) -> Json {
    Json::obj(vec![
        ("label", Json::str(&p.label)),
        ("frames", Json::num(p.frames as f64)),
        ("density", Json::num(p.density as f64)),
        ("ref_height", Json::num(p.ref_height)),
        ("depth_near", Json::num(p.depth_range.0)),
        ("depth_far", Json::num(p.depth_range.1)),
        ("walk_speed", Json::num(p.walk_speed)),
        ("camera", camera_to_json(&p.camera)),
        ("fps_scale", Json::num(p.fps_scale)),
        (
            "noise",
            Json::obj(vec![
                ("miss", Json::num(p.noise.miss)),
                ("conf_loss", Json::num(p.noise.conf_loss)),
            ]),
        ),
    ])
}

fn phase_from_json(v: &Json) -> Result<PhaseSpec, String> {
    let str_field = |key: &str| {
        v.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("phase: missing '{key}'"))
    };
    let num = |key: &str| {
        v.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("phase: missing '{key}'"))
    };
    let noise = v.get("noise").ok_or("phase: missing 'noise'")?;
    Ok(PhaseSpec {
        label: str_field("label")?,
        frames: v
            .get("frames")
            .and_then(Json::as_usize)
            .ok_or("phase: missing 'frames'")? as u64,
        density: v
            .get("density")
            .and_then(Json::as_usize)
            .ok_or("phase: missing 'density'")?,
        ref_height: num("ref_height")?,
        depth_range: (num("depth_near")?, num("depth_far")?),
        walk_speed: num("walk_speed")?,
        camera: camera_from_json(
            v.get("camera").ok_or("phase: missing 'camera'")?,
        )?,
        fps_scale: num("fps_scale")?,
        noise: NoiseProfile {
            miss: noise
                .get("miss")
                .and_then(Json::as_f64)
                .ok_or("noise: missing 'miss'")?,
            conf_loss: noise
                .get("conf_loss")
                .and_then(Json::as_f64)
                .ok_or("noise: missing 'conf_loss'")?,
        },
    })
}

/// Serialize a scenario to its versioned JSON document.
pub fn to_json(spec: &ScenarioSpec) -> Json {
    let streams = spec.streams.iter().map(|s| {
        Json::obj(vec![
            ("label", Json::str(&s.label)),
            ("join_s", Json::num(s.join_s)),
            ("phases", Json::arr(s.phases.iter().map(phase_to_json))),
        ])
    });
    Json::obj(vec![
        ("schema", Json::str(SCHEMA_TAG)),
        ("version", Json::num(SCENARIO_VERSION as f64)),
        ("name", Json::str(&spec.name)),
        ("description", Json::str(&spec.description)),
        ("seed", Json::num(spec.seed as f64)),
        ("width", Json::num(spec.width as f64)),
        ("height", Json::num(spec.height as f64)),
        ("base_fps", Json::num(spec.base_fps)),
        ("watts_budget", Json::num(spec.watts_budget)),
        ("streams", Json::arr(streams)),
    ])
}

/// Parse and validate a scenario from its JSON document.
pub fn from_json(doc: &Json) -> Result<ScenarioSpec, String> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("missing 'schema' tag")?;
    if schema != SCHEMA_TAG {
        return Err(format!("wrong schema: {schema:?} (want {SCHEMA_TAG:?})"));
    }
    let version = doc
        .get("version")
        .and_then(Json::as_usize)
        .ok_or("missing 'version'")?;
    if version != SCENARIO_VERSION as usize {
        return Err(format!(
            "scenario version {version} unsupported (this build reads \
             version {SCENARIO_VERSION})"
        ));
    }
    let str_field = |key: &str| {
        doc.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing '{key}'"))
    };
    let mut streams = Vec::new();
    for s in doc
        .get("streams")
        .and_then(Json::as_arr)
        .ok_or("missing 'streams'")?
    {
        let phases = s
            .get("phases")
            .and_then(Json::as_arr)
            .ok_or("stream: missing 'phases'")?
            .iter()
            .map(phase_from_json)
            .collect::<Result<Vec<_>, String>>()?;
        streams.push(StreamSpec {
            label: s
                .get("label")
                .and_then(Json::as_str)
                .ok_or("stream: missing 'label'")?
                .to_string(),
            join_s: s
                .get("join_s")
                .and_then(Json::as_f64)
                .ok_or("stream: missing 'join_s'")?,
            phases,
        });
    }
    let spec = ScenarioSpec {
        name: str_field("name")?,
        description: str_field("description")?,
        seed: doc
            .get("seed")
            .and_then(Json::as_usize)
            .ok_or("missing 'seed'")? as u64,
        width: doc
            .get("width")
            .and_then(Json::as_usize)
            .ok_or("missing 'width'")? as u32,
        height: doc
            .get("height")
            .and_then(Json::as_usize)
            .ok_or("missing 'height'")? as u32,
        base_fps: doc
            .get("base_fps")
            .and_then(Json::as_f64)
            .ok_or("missing 'base_fps'")?,
        watts_budget: doc
            .get("watts_budget")
            .and_then(Json::as_f64)
            .ok_or("missing 'watts_budget'")?,
        streams,
    };
    spec.validate()?;
    Ok(spec)
}

/// Write a scenario to `path` as pretty JSON (parent dirs created).
pub fn save(spec: &ScenarioSpec, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, to_json(spec).to_pretty())
}

/// Load and validate a scenario from `path`.
pub fn load(path: &Path) -> Result<ScenarioSpec, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    let doc =
        Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    from_json(&doc).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScenarioSpec {
        ScenarioSpec::new(
            "store-unit",
            "store round-trip scenario",
            vec![
                StreamSpec::new(
                    "cam0",
                    vec![
                        PhaseSpec::new("day", 40),
                        PhaseSpec::new("night", 50)
                            .noise(NoiseProfile::NIGHT)
                            .camera(CameraMotion::Walking { pan_speed: 12.0 })
                            .fps_scale(0.6),
                    ],
                ),
                StreamSpec::new(
                    "cam1",
                    vec![PhaseSpec::new("drive", 30)
                        .camera(CameraMotion::Vehicle { flow_speed: 18.0 })],
                )
                .join_at(2.5),
            ],
        )
        .seed(99)
        .watts_budget(5.5)
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let s = sample();
        let doc = to_json(&s);
        assert_eq!(from_json(&doc).unwrap(), s);
        let reparsed = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(from_json(&reparsed).unwrap(), s);
    }

    #[test]
    fn file_roundtrip() {
        let s = sample();
        let dir = std::env::temp_dir().join("tod_scenario_store_test");
        let path = dir.join("scenario.json");
        save(&s, &path).unwrap();
        assert_eq!(load(&path).unwrap(), s);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_schema_and_version_rejected() {
        let doc = to_json(&sample());
        let mut wrong_schema = doc.clone();
        if let Json::Obj(m) = &mut wrong_schema {
            m.insert("schema".into(), Json::str("not-a-scenario"));
        }
        assert!(from_json(&wrong_schema).unwrap_err().contains("schema"));
        let mut wrong_version = doc;
        if let Json::Obj(m) = &mut wrong_version {
            m.insert("version".into(), Json::num(42.0));
        }
        assert!(from_json(&wrong_version).unwrap_err().contains("version 42"));
    }

    #[test]
    fn invalid_payload_rejected_by_validation() {
        let mut bad = sample();
        bad.streams[0].phases[0].frames = 0;
        assert!(from_json(&to_json(&bad)).is_err());
        assert!(load(Path::new("/nonexistent/scenario.json")).is_err());
    }
}
